"""Pallas kernel sweeps (interpret mode) vs pure-jnp oracles."""
import numpy as np
import pytest

import jax.numpy as jnp

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.filter_project.kernel import filter_scan, parse_i32
from repro.kernels.filter_project.ref import filter_scan_ref, parse_i32_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import mha_ref

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# filter_project
# ---------------------------------------------------------------------------
FILTER_SHAPES = [(2048, 2048), (8192, 2048), (16384, 1024), (4096, 512)]
PROGRAMS = [
    (("gt", 0, 50),),
    (("gt", 0, 50), ("lt", 1, 0.25), ("and",)),
    (("gt", 0, 80), ("le", 0, 10), ("or",), ("ne", 1, 0.5), ("and",)),
    (("eq", 0, 3), ("not",)),
]


class TestFilterScan:
    @pytest.mark.parametrize("n,block", FILTER_SHAPES)
    @pytest.mark.parametrize("prog", PROGRAMS)
    def test_mask_and_counts_match_ref(self, n, block, prog):
        a = jnp.asarray(RNG.integers(0, 100, n).astype(np.int32))
        b = jnp.asarray(RNG.random(n).astype(np.float32))
        nrows = n - 17
        m1, c1 = filter_scan((a, b), prog, nrows, block=block,
                             interpret=True)
        m2, c2 = filter_scan_ref((a, b), prog, nrows, block)
        assert bool((m1 == m2).all())
        assert bool((c1 == c2).all())

    def test_rows_beyond_nrows_never_match(self):
        n, block = 4096, 1024
        a = jnp.ones((n,), jnp.int32) * 99
        m, _ = filter_scan((a,), (("gt", 0, 0),), 100, block=block,
                           interpret=True)
        assert int(m.sum()) == 100

    @settings(max_examples=20, deadline=None)
    @given(nrows=st.integers(0, 4096), thr=st.integers(-5, 105))
    def test_property_count_matches_numpy(self, nrows, thr):
        n, block = 4096, 1024
        a_np = RNG.integers(0, 100, n).astype(np.int32)
        m, c = filter_scan((jnp.asarray(a_np),), (("gt", 0, thr),), nrows,
                           block=block, interpret=True)
        expect = int((a_np[:nrows] > thr).sum())
        assert int(m.sum()) == expect == int(c.sum())


class TestParseI32:
    @pytest.mark.parametrize("n,block", [(2048, 2048), (8192, 2048)])
    def test_digits_roundtrip(self, n, block):
        vals = np.concatenate([
            np.array([0, 1, 999_999_999, 123_456_789], np.int64),
            RNG.integers(0, 10**9, n - 4)]).astype(np.int64)
        digits = np.zeros((n, 10), np.uint8)
        v = vals.copy()
        for k in range(9, -1, -1):
            digits[:, k] = (v % 10) + 48
            v //= 10
        d = jnp.asarray(digits)
        out = parse_i32(d, block=block, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), vals.astype(np.int32))
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(parse_i32_ref(d)))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (B, Hq, Hkv, T, S, D, causal, window)
    (1, 4, 4, 256, 256, 64, True, None),
    (2, 8, 2, 128, 256, 64, True, None),     # GQA + offset (decode-style)
    (1, 4, 2, 256, 256, 128, True, 128),     # sliding window
    (1, 2, 2, 256, 256, 64, False, None),    # bidirectional
    (1, 16, 1, 128, 128, 64, True, None),    # MQA
]


class TestFlashAttention:
    @pytest.mark.parametrize("case", ATTN_CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, case, dtype):
        b, hq, hkv, t, s, d, causal, window = case
        q = jnp.asarray(RNG.standard_normal((b, hq, t, d)), dtype)
        k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
        v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
        ref = mha_ref(q, k, v, causal=causal, window=window)
        atol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=atol)

    def test_block_sizes(self):
        b, hq, hkv, t, s, d = 1, 2, 2, 256, 256, 64
        q = jnp.asarray(RNG.standard_normal((b, hq, t, d)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
        ref = mha_ref(q, k, v)
        for bq, bk in [(64, 64), (128, 256), (256, 128)]:
            out = flash_attention(q, k, v, block_q=bq, block_k=bk,
                                  interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=2e-5)

    def test_vjp_path_runs(self):
        import jax

        from repro.kernels.flash_attention.ops import attention

        q = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)

        def loss(q, k, v):
            return attention(q, k, v, True, None, None, "pallas").sum()

        g = jax.grad(loss)(q, k, v)
        assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
DECODE_CASES = [
    (2, 8, 2, 512, 64, None),
    (1, 4, 4, 256, 128, None),
    (3, 8, 4, 384, 64, 128),
    (1, 32, 8, 1024, 128, None),
]


class TestDecodeAttention:
    @pytest.mark.parametrize("case", DECODE_CASES)
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, case, dtype):
        b, hq, hkv, s, d, window = case
        q = jnp.asarray(RNG.standard_normal((b, hq, d)), dtype)
        k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
        v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), dtype)
        kv_len = jnp.asarray(RNG.integers(1, s + 1, b).astype(np.int32))
        out = decode_attention(q, k, v, kv_len, window=window,
                               interpret=True)
        ref = decode_ref(q, k, v, kv_len, window=window)
        atol = 2e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=atol)

    def test_len_one_cache(self):
        b, hq, hkv, s, d = 1, 4, 2, 128, 64
        q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((b, hkv, s, d)), jnp.float32)
        kv_len = jnp.asarray([1], jnp.int32)
        out = decode_attention(q, k, v, kv_len, interpret=True)
        ref = decode_ref(q, k, v, kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)
