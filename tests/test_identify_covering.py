"""Algorithm 1 (SE identification) + CE construction (Definition 4)."""
import pytest

from repro.core import (build_covering_expression, fingerprint,
                        identify_similar_subexpressions)
from repro.relational import I32, STR, Schema, expr as E, logical as L

S = Schema.of(("a", I32), ("b", I32), ("c", STR(4)))


def sc():
    return L.scan("t", S)


class TestIdentify:
    def test_running_example_counts(self, hr_session):
        from conftest import hr_queries

        from repro.relational.rules import optimize_single

        plans = [optimize_single(q) for q in hr_queries(hr_session)]
        ses = identify_similar_subexpressions(plans)
        # ψ2-analog: filter/project over employees shared by all 3 queries
        ms = sorted(se.m for se in ses)
        assert len(ses) >= 3
        assert any(se.m == 3 for se in ses), ms

    def test_stops_high_when_no_unfriendly_ops(self):
        # Whole plans match and contain no joins: only ONE SE (the root),
        # not one per level — Algorithm 1 stops as high as possible.
        p1 = sc().filter(E.cmp("a", ">", 1)).project("a")
        p2 = sc().filter(E.cmp("a", ">", 2)).project("a", "b")
        ses = identify_similar_subexpressions([p1, p2])
        assert len(ses) == 1
        assert ses[0].occurrences[0].node.label == "project"

    def test_descends_through_unfriendly_roots(self):
        # Join roots are never SEs, but their friendly inputs are found.
        l1 = sc().filter(E.cmp("a", ">", 1))
        l2 = sc().filter(E.cmp("a", ">", 5))
        other = L.scan("u", S)
        p1 = l1.join(other, "a", "a")  # join: unfriendly root
        p2 = l2.join(other, "a", "a")
        ses = identify_similar_subexpressions([p1, p2])
        labels = {se.occurrences[0].node.label for se in ses}
        assert "filter" in labels
        assert "join" not in labels

    def test_threshold_k(self):
        p1 = sc().filter(E.cmp("a", ">", 1))
        p2 = sc().filter(E.cmp("a", ">", 2))
        p3 = L.scan("u", S).filter(E.cmp("b", ">", 3))
        assert len(identify_similar_subexpressions([p1, p2, p3], k=2)) == 1
        assert len(identify_similar_subexpressions([p1, p2, p3], k=3)) == 0

    def test_syntactically_equal_joins_shared_inside_se(self):
        # cache-unfriendly ops are shareable when syntactically equal,
        # surrounded by friendly operators (the ψ1 case of the paper).
        def mk(sel):
            return (sc().filter(E.cmp("a", ">", 0))
                    .join(L.scan("u", S).filter(E.cmp("b", ">", 0)),
                          "a", "b")
                    .project(*sel))

        p1, p2 = mk(("a",)), mk(("b",))
        ses = identify_similar_subexpressions([p1, p2])
        assert any(se.occurrences[0].node.label == "project" and
                   se.occurrences[0].node.children[0].label == "join"
                   for se in ses)


class TestCovering:
    def test_or_merge_and_union_cols(self):
        p1 = sc().filter(E.cmp("a", ">", 10)).project("a")
        p2 = sc().filter(E.cmp("b", "==", 5)).project("b")
        ses = identify_similar_subexpressions([p1, p2])
        ce = build_covering_expression(ses[0])
        proj = ce.tree
        filt = proj.children[0]
        assert isinstance(filt.pred, E.Or)
        assert set(proj.cols) >= {"a", "b"}

    def test_ce_fingerprint_matches_members(self):
        p1 = sc().filter(E.cmp("a", ">", 10)).project("a")
        p2 = sc().filter(E.cmp("b", "==", 5)).project("b")
        ses = identify_similar_subexpressions([p1, p2])
        ce = build_covering_expression(ses[0])
        assert fingerprint(ce.tree) == ses[0].psi

    def test_equal_members_produce_identical_ce(self):
        p1 = sc().filter(E.cmp("a", ">", 10)).project("a")
        p2 = sc().filter(E.cmp("a", ">", 10)).project("a")
        ses = identify_similar_subexpressions([p1, p2])
        ce = build_covering_expression(ses[0])
        assert not ce.tree.divergent
        assert E.canonical(ce.tree.children[0].pred) == E.canonical(
            E.cmp("a", ">", 10))

    def test_duplicate_predicates_removed_in_or(self):
        p1 = sc().filter(E.cmp("a", ">", 10))
        p2 = sc().filter(E.cmp("a", ">", 10))
        p3 = sc().filter(E.cmp("a", "<", 2))
        ses = identify_similar_subexpressions([p1, p2, p3])
        ce = build_covering_expression(ses[0])
        assert isinstance(ce.tree.pred, E.Or)
        assert len(ce.tree.pred.parts) == 2  # dedup of the repeated pred
