"""Single-query optimizer semantics + cost model properties (Eq. 1–3)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from oracle import execute_oracle, multiset
from repro.core.costmodel import price_ce
from repro.core.covering import build_covering_expressions
from repro.core.identify import identify_similar_subexpressions
from repro.relational import (ExecContext, I32, Schema, execute, expr as E,
                              logical as L, make_storage)
from repro.relational.rules import optimize_single
from repro.relational.stats import (RelationalCostModel, StatsRegistry,
                                    build_table_stats, required_columns,
                                    selectivity)

S_FACT = Schema.of(("a", I32), ("b", I32), ("c", I32))
S_DIM = Schema.of(("k", I32), ("v", I32))


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(3)
    fact = {c: rng.integers(0, 50, 600).astype(np.int32)
            for c in ("a", "b", "c")}
    dim = {"k": np.arange(64, dtype=np.int32),
           "v": rng.integers(0, 50, 64).astype(np.int32)}
    st_f, _ = make_storage("fact", S_FACT, 600, "columnar", cols=fact)
    st_d, _ = make_storage("dim", S_DIM, 64, "columnar", cols=dim)
    reg = StatsRegistry()
    reg.register("fact", build_table_stats(fact, 600, S_FACT))
    reg.register("dim", build_table_stats(dim, 64, S_DIM))
    return {"storage": {"fact": st_f, "dim": st_d},
            "oracle": {"fact": (S_FACT, 600, fact),
                       "dim": (S_DIM, 64, dim)},
            "reg": reg}


def _plans():
    f = L.scan("fact", S_FACT)
    d = L.scan("dim", S_DIM)
    return [
        f.filter(E.cmp("a", ">", 25)).project("a", "b"),
        f.project("a", "b").filter(E.cmp("a", ">", 25)),
        f.join(d, "a", "k").filter(E.and_(E.cmp("b", "<", 30),
                                          E.cmp("v", ">", 10))),
        f.filter(E.cmp("c", "<", 10)).join(d, "a", "k")
         .project("a", "v").sort("v"),
        f.groupby("a").agg(("n", "count", ""), ("s", "sum", "b")),
    ]


class TestSingleQueryOptimizer:
    def test_semantics_preserved(self, data):
        for plan in _plans():
            opt = optimize_single(plan)
            got = execute(opt, ExecContext(catalog=data["storage"]))
            want = multiset(execute_oracle(plan, data["oracle"]),
                            plan.schema)
            assert got.row_multiset() == want, L.explain(plan)

    def test_filter_pushed_through_project(self):
        p = (L.scan("fact", S_FACT).project("a", "b")
             .filter(E.cmp("a", ">", 5)))
        opt = optimize_single(p)
        # filter must now sit below the projection
        assert isinstance(opt, L.Project)

    def test_join_filter_split_by_side(self):
        p = (L.scan("fact", S_FACT).join(L.scan("dim", S_DIM), "a", "k")
             .filter(E.and_(E.cmp("b", "<", 30), E.cmp("v", ">", 10))))
        opt = optimize_single(p)

        def filters_below_join(n, below=False):
            found = []
            if isinstance(n, L.Filter) and below:
                found.append(n)
            for c in n.children:
                found += filters_below_join(
                    c, below or isinstance(n, L.Join))
            return found

        assert len(filters_below_join(opt)) == 2

    def test_scan_pruning_inserts_projects(self):
        p = L.scan("fact", S_FACT).filter(E.cmp("a", ">", 5)).project("a")
        opt = optimize_single(p)
        from repro.core.plan import walk

        scans = [n for n in walk(opt) if isinstance(n, L.Scan)]
        parents = [n for n in walk(opt)
                   if scans[0] in n.children]
        assert isinstance(parents[0], (L.Project, L.Filter))


class TestSelectivity:
    def test_bounds(self, data):
        reg = data["reg"]
        for e in [E.cmp("a", ">", 25), E.cmp("a", "==", 3),
                  E.and_(E.cmp("a", ">", 10), E.cmp("b", "<", 5)),
                  E.or_(E.cmp("a", ">", 49), E.cmp("a", "<", 1)),
                  E.not_(E.cmp("c", "!=", 7))]:
            s = selectivity(e, reg)
            assert 0.0 <= s <= 1.0, (E.pretty(e), s)

    def test_range_monotone(self, data):
        reg = data["reg"]
        sels = [selectivity(E.cmp("a", "<", t), reg)
                for t in (5, 15, 25, 35, 45)]
        assert sels == sorted(sels)

    def test_estimates_close_to_truth(self, data):
        reg = data["reg"]
        rng_vals = data["oracle"]["fact"][2]["a"]
        for thr in (10, 25, 40):
            est = selectivity(E.cmp("a", ">", thr), reg)
            true = float((rng_vals > thr).mean())
            assert abs(est - true) < 0.1, (thr, est, true)


class TestCostModelEquations:
    def _ces(self, data, plans):
        plans = [optimize_single(p) for p in plans]
        ses = identify_similar_subexpressions(plans)
        ces = build_covering_expressions(ses)
        cm = RelationalCostModel(data["reg"])
        for ce in ces:
            price_ce(ce, cm)
        return ces, cm

    def test_eq1_unshared_cost_is_sum(self, data):
        f = L.scan("fact", S_FACT)
        plans = [f.filter(E.cmp("a", ">", 10)).project("a"),
                 f.filter(E.cmp("a", ">", 30)).project("b")]
        ces, cm = self._ces(data, plans)
        ce = ces[0]
        total = sum(cm.execution_cost(o.node)
                    for o in ce.se.occurrences)
        assert ce.cost_detail["C_omega"] == pytest.approx(total)

    def test_eq2_structure(self, data):
        f = L.scan("fact", S_FACT)
        plans = [f.filter(E.cmp("a", ">", 10)),
                 f.filter(E.cmp("a", ">", 30))]
        ces, cm = self._ces(data, plans)
        ce = ces[0]
        d = ce.cost_detail
        assert d["C_Omega"] == pytest.approx(
            d["C_E_star"] + d["C_W"] + d["m"] * d["C_R"])

    def test_eq3_value_increases_with_m(self, data):
        f = L.scan("fact", S_FACT)
        two = [f.filter(E.cmp("a", ">", 10)),
               f.filter(E.cmp("a", ">", 30))]
        three = two + [f.filter(E.cmp("a", ">", 20))]
        ces2, _ = self._ces(data, two)
        ces3, _ = self._ces(data, three)
        by_label2 = max(ce.value for ce in ces2)
        by_label3 = max(ce.value for ce in ces3)
        assert by_label3 > by_label2

    def test_weight_is_rows_times_width(self, data):
        f = L.scan("fact", S_FACT)
        plans = [f.filter(E.cmp("a", ">", 10)).project("a"),
                 f.filter(E.cmp("a", ">", 30)).project("a")]
        ces, cm = self._ces(data, plans)
        for ce in ces:
            assert ce.weight == cm.output_rows(ce.tree) \
                * ce.tree.schema.row_mem_bytes


class TestRequiredColumns:
    def test_join_needs_keys_plus_outputs(self):
        p = (L.scan("fact", S_FACT).join(L.scan("dim", S_DIM), "a", "k")
             .project("b", "v"))
        req = required_columns(p)
        from repro.core.plan import walk

        for n in walk(p):
            if isinstance(n, L.Scan) and n.table == "fact":
                assert req[id(n)] == frozenset({"a", "b"})
            if isinstance(n, L.Scan) and n.table == "dim":
                assert req[id(n)] == frozenset({"k", "v"})
