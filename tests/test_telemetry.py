"""Unified telemetry subsystem (PR 9): span tracer well-formedness
(including under fault injection), Chrome trace-event export, the
zero-cost disabled mode, histogram percentile edge cases, the pinned
``explain()`` key sets, metrics-report contents, and event routing
through the one metrics registry.
"""
import json
import math
import time

import numpy as np
import pytest

from repro.core.faults import FaultConfig
from repro.core.telemetry import (Histogram, MetricsRegistry, NOOP_SPAN,
                                  NOOP_TRACER, SpanTracer)
from repro.relational import (EXPLAIN_CE_KEYS, EXPLAIN_DONE_KEYS,
                              EXPLAIN_DONE_OPTIONAL_KEYS,
                              EXPLAIN_FAILED_KEYS, ExplainReport, I32,
                              MemoryConfig, QueryService, Relation, Schema,
                              Session, SessionConfig, Telemetry,
                              expr as E, logical as L, make_storage)

S = Schema.of(("a", I32), ("b", I32), ("c", I32))
NROWS = 2000


def _mk_session(budget=1 << 24, *, config=None) -> Session:
    rng = np.random.default_rng(7)
    cols = {c: rng.integers(0, 100, NROWS).astype(np.int32)
            for c in ("a", "b", "c")}
    if config is None:
        config = SessionConfig(memory=MemoryConfig(budget_bytes=budget))
    sess = Session.from_config(config)
    st, _ = make_storage("t", S, NROWS, "columnar", cols=cols)
    sess.register(st)
    return sess


def _recurring(sess, n=3):
    """n identical queries: the window forms (and later re-hits) a CE,
    which is what exercises materialize + cached_read calibration."""
    return [sess.table("t").filter(E.cmp("a", ">", 50)).project("a", "b")
            for _ in range(n)]


def _all_spans(tracer):
    return [sp for root in tracer.finished for _, sp in root.walk()]


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------
class TestSpanTracer:
    def test_nesting_follows_with_structure(self):
        tr = SpanTracer()
        with tr.span("outer", k=1) as outer:
            with tr.span("inner"):
                pass
        assert [s.name for _, s in outer.walk()] == ["outer", "inner"]
        assert tr.finished == [outer] and tr._stack == []
        assert outer.duration is not None and outer.duration >= 0

    def test_span_closes_and_marks_error_on_raise(self):
        tr = SpanTracer()
        with pytest.raises(RuntimeError):
            with tr.span("w"):
                with tr.span("child"):
                    raise RuntimeError("boom")
        spans = _all_spans(tr)
        assert {s.name for s in spans} == {"w", "child"}
        assert all(s.t_end is not None for s in spans)
        assert all(s.status == "error" for s in spans)
        assert tr._stack == []

    def test_leaked_child_closed_by_parent_exit(self):
        tr = SpanTracer()
        with tr.span("parent") as p:
            leaked = tr.span("leaked")
            leaked.__enter__()      # never exited (simulated escape)
        assert tr._stack == []
        assert leaked.t_end is not None and leaked.status == "error"
        assert p.t_end is not None and p.children == [leaked]

    def test_lifecycle_spans_well_formed_under_fault_injection(self):
        # every window dies at window_close, yet every opened span must
        # close (error-marked) and the stack must never wedge
        cfg = SessionConfig(
            memory=MemoryConfig(budget_bytes=1 << 24)
        ).with_faults(FaultConfig(seed=0, rates={"window_close": 1.0}))
        sess = _mk_session(config=cfg)
        tr = sess.enable_tracing()
        svc = QueryService(sess, max_batch=3)
        handles = [svc.submit(q) for q in _recurring(sess)]
        assert all(h.done and h.failed for h in handles)
        assert tr._stack == [], "a span was left open by the fault"
        spans = _all_spans(tr)
        assert spans, "tracing collected nothing"
        assert all(s.t_end is not None for s in spans)
        # isolation catches the fault INSIDE the window span, which
        # records it as an attribute and still closes cleanly
        assert any(s.name == "window" and "error" in s.attrs
                   for s in spans)
        # the service survives and the NEXT window traces cleanly
        h = svc.submit(_recurring(sess, 1)[0])
        svc.flush()
        assert h.done and tr._stack == []


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
class TestExport:
    def _traced_session(self):
        sess = _mk_session()
        sess.enable_tracing()
        svc = QueryService(sess, max_batch=3)
        for _ in range(2):                   # second window re-hits CE
            for q in _recurring(sess):
                svc.submit(q)
            svc.flush()
        return sess

    def test_chrome_trace_valid_and_covers_lifecycle(self, tmp_path):
        sess = self._traced_session()
        path = tmp_path / "trace.json"
        doc = sess.telemetry().export_chrome_trace(str(path))
        # valid, round-trippable trace-event JSON
        assert set(doc) == {"traceEvents", "displayTimeUnit"}
        assert json.loads(path.read_text()) == json.loads(json.dumps(doc))
        names = set()
        for ev in doc["traceEvents"]:
            assert ev["ph"] == "X"
            assert isinstance(ev["ts"], float) and ev["dur"] >= 0.0
            assert isinstance(ev["name"], str)
            json.dumps(ev["args"])           # attrs must be jsonable
            names.add(ev["name"])
        # the acceptance lifecycle: submit -> window -> MQO -> dispatch
        # -> resolve, plus the executor-side CE/H2D spans
        assert {"submit", "window", "canonicalize", "mqo",
                "mqo.identify", "mqo.solve", "execute",
                "resolve"} <= names
        assert names & {"dispatch.batched", "ce.materialize", "scan.h2d"}

    def test_jsonl_export_one_record_per_span(self):
        sess = self._traced_session()
        text = sess.telemetry().export_jsonl()
        recs = [json.loads(ln) for ln in text.splitlines()]
        assert len(recs) == len(_all_spans(sess.telemetry().tracer))
        for r in recs:
            assert {"name", "depth", "ts", "dur", "status"} <= set(r)
        assert any(r["depth"] > 0 for r in recs)

    def test_noop_tracer_exports_empty_doc(self):
        doc = NOOP_TRACER.export_chrome_trace()
        assert doc == {"traceEvents": [], "displayTimeUnit": "ms"}
        assert NOOP_TRACER.export_jsonl() == ""


# ---------------------------------------------------------------------------
# disabled mode is free
# ---------------------------------------------------------------------------
class TestDisabledMode:
    def test_disabled_span_is_the_singleton_noop(self):
        tel = Telemetry()
        assert tel.tracer is NOOP_TRACER and not tel.tracing
        assert tel.span("anything", big=object()) is NOOP_SPAN
        assert tel.span("other") is tel.span("third")   # one instance
        assert NOOP_SPAN.set(x=1) is NOOP_SPAN
        with tel.span("x") as sp:
            assert sp is NOOP_SPAN

    def test_disabled_mode_never_reads_the_clock(self):
        calls = [0]

        def clock():
            calls[0] += 1
            return time.monotonic()

        tel = Telemetry(clock=clock)
        for _ in range(100):
            with tel.span("hot"):
                pass
        assert calls[0] == 0, "disabled tracing touched the clock"
        tel.enable_tracing()
        with tel.span("hot"):
            pass
        assert calls[0] == 2                # enter + exit, nothing else

    def test_service_span_guard_skips_attr_building(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=2)
        assert svc._span("window", window=0) is NOOP_SPAN
        sess.enable_tracing()
        assert svc._span("window", window=0) is not NOOP_SPAN
        sess.telemetry().disable_tracing()
        assert svc._span("window", window=0) is NOOP_SPAN

    def test_disabled_run_retains_no_spans(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=3)
        for q in _recurring(sess):
            svc.submit(q)
        svc.flush()
        assert sess.telemetry().tracer is NOOP_TRACER
        assert list(sess.telemetry().tracer.finished) == []


# ---------------------------------------------------------------------------
# histogram percentiles
# ---------------------------------------------------------------------------
class TestHistogram:
    def test_empty_percentiles_are_nan(self):
        h = Histogram()
        assert math.isnan(h.percentile(0.5))
        assert math.isnan(h.mean)
        d = h.as_dict()
        assert d["count"] == 0 and d["min"] is None and d["max"] is None

    def test_single_value_every_percentile_exact(self):
        h = Histogram(edges=(1.0, 10.0, 100.0))
        h.observe(42.0)
        for q in (0.0, 0.5, 0.99, 1.0):
            assert h.percentile(q) == 42.0

    def test_p0_p100_exact_min_max(self):
        h = Histogram(edges=(1.0, 10.0, 100.0))
        for v in (0.3, 2.0, 5.0, 37.0, 512.0):   # under- and overflow
            h.observe(v)
        assert h.percentile(0.0) == 0.3
        assert h.percentile(1.0) == 512.0
        assert h.count == 5 and h.total == pytest.approx(556.3)

    def test_interpolation_bounded_by_observations(self):
        h = Histogram(edges=(1.0, 10.0, 100.0))
        for v in (2.0, 3.0, 4.0, 5.0, 6.0):
            h.observe(v)
        for q in (0.1, 0.5, 0.9):
            assert 2.0 <= h.percentile(q) <= 6.0
        assert h.percentile(0.5) == pytest.approx(4.0, abs=2.0)

    def test_quantile_clamped_to_unit_interval(self):
        h = Histogram(edges=(1.0,))
        h.observe(0.5)
        h.observe(2.0)
        assert h.percentile(-3.0) == 0.5
        assert h.percentile(7.0) == 2.0

    def test_non_ascending_edges_rejected(self):
        with pytest.raises(ValueError):
            Histogram(edges=(1.0, 1.0, 2.0))
        with pytest.raises(ValueError):
            Histogram(edges=(5.0, 1.0))

    def test_registry_create_on_first_use(self):
        reg = MetricsRegistry()
        reg.inc("x")
        reg.inc("x", 2)
        reg.observe("lat", 0.5)
        reg.ewma("e").observe(3.0)
        reg.set_gauge("g", 9.0)
        assert reg.value("x") == 3 and reg.value("never") == 0
        snap = reg.snapshot()
        assert snap["counters"]["x"] == 3
        assert snap["gauges"]["g"] == 9.0
        assert snap["ewmas"]["e"] == {"value": 3.0, "n": 1}
        assert snap["histograms"]["lat"]["count"] == 1


# ---------------------------------------------------------------------------
# the pinned explain schema
# ---------------------------------------------------------------------------
class TestExplainSchema:
    def test_done_report_key_set_pinned(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=3)
        handles = [svc.submit(q) for q in _recurring(sess)]
        for h in handles:
            d = h.explain()
            assert EXPLAIN_DONE_KEYS <= set(d)
            assert set(d) <= (EXPLAIN_DONE_KEYS
                              | EXPLAIN_DONE_OPTIONAL_KEYS)
            for ce in d["ces"]:
                assert EXPLAIN_CE_KEYS <= set(ce)
                assert set(ce) <= EXPLAIN_CE_KEYS | {"partitions"}
            rep = h.explain_report()
            assert isinstance(rep, ExplainReport)
            assert rep.status == "done" and rep.as_dict() == d

    def test_failed_report_key_set_pinned(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=1, mqo=False)
        h = svc.submit(Relation(L.scan("ghost", S, "columnar"), sess))
        assert h.done and h.failed
        d = h.explain()
        assert set(d) == EXPLAIN_FAILED_KEYS
        assert h.explain_report().status == "failed"

    def test_window_death_report_key_set_pinned(self):
        cfg = SessionConfig(
            memory=MemoryConfig(budget_bytes=1 << 24)
        ).with_faults(FaultConfig(seed=0, rates={"window_close": 1.0}))
        sess = _mk_session(config=cfg)
        svc = QueryService(sess, max_batch=2)
        handles = [svc.submit(q) for q in _recurring(sess, 2)]
        for h in handles:
            assert set(h.explain()) == EXPLAIN_FAILED_KEYS
            assert h.explain()["submitted"]


# ---------------------------------------------------------------------------
# the unified metrics report
# ---------------------------------------------------------------------------
class TestMetricsReport:
    def _warm_service(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=3)
        for _ in range(2):                   # window 2 re-reads the CE
            for q in _recurring(sess):
                svc.submit(q)
            svc.flush()
        return sess, svc

    def test_report_contents(self):
        sess, svc = self._warm_service()
        rep = svc.metrics_report()
        assert rep == sess.metrics_report()

        counters = rep["registry"]["counters"]
        assert counters["queries.submitted"] == 6
        assert counters["queries.executed"] == 6
        assert counters["queries.succeeded"] == 6
        assert counters.get("queries.failed", 0) == 0
        assert counters["windows.closed"] == 2
        assert counters["bytes.ce_cached_read"] > 0

        # per-template latency percentiles
        assert rep["latency"]["all"]["count"] == 6
        assert len(rep["latency"]["families"]) == 1
        fam = next(iter(rep["latency"]["families"].values()))
        assert fam["count"] == 6 and fam["p50"] >= 0.0
        assert rep["arrival_interval_ewma_s"]["n"] == 5

        # every pool reports occupancy + a hit rate
        assert rep["pools"]
        for st in rep["pools"].values():
            assert 0.0 <= st["hit_rate"] <= 1.0
        assert any(st["hits"] > 0 for st in rep["pools"].values())

    def test_calibration_has_both_kinds(self):
        sess, svc = self._warm_service()
        cal = svc.metrics_report()["calibration"]
        kinds = cal["kinds"]
        assert cal["n_samples"] >= 2
        assert "materialize" in kinds and "cached_read" in kinds
        for k in ("materialize", "cached_read"):
            row = kinds[k]
            assert row["n"] >= 1
            assert row["predicted_cost"] > 0
            assert row["measured_seconds"] > 0
        # the session-level calibration surface agrees
        assert sess.cost_model.calibration() == cal

    def test_fault_and_degradation_events_in_registry(self):
        # one scan_h2d fault inside the shared CE materialization: its
        # consumers fall back to residual plans -> degradation events
        # plus fault.* counters, all countable from the ONE registry
        cfg = SessionConfig(
            memory=MemoryConfig(budget_bytes=1 << 24)
        ).with_faults(FaultConfig(seed=0, schedule={"scan_h2d": (0,)}))
        sess = _mk_session(config=cfg)
        svc = QueryService(sess, max_batch=3)
        handles = [svc.submit(q) for q in _recurring(sess)]
        assert not any(h.failed for h in handles)
        reg = sess.telemetry().registry
        assert reg.value("events.total") >= 1
        assert reg.value("events.action.fallback") >= 1
        inj = sess.fault_injector.report()
        assert reg.value("fault.fired.scan_h2d") == \
            inj["fired"]["scan_h2d"]
        assert reg.value("fault.fired.total") == inj["n_fired"]
        assert reg.value("fault.invocations.scan_h2d") == \
            inj["invocations"]["scan_h2d"]
        rep = svc.metrics_report()
        assert rep["faults"] == inj
