"""Async serving front (ISSUE 10): concurrent submission, the
background window closer, adaptive windowing, per-tenant admission
control, and the async fault soak.

Covers:
  * submit/await round trips and async-vs-sync bit-identity on the
    same plan set (both fronts route through QueryService._run_window);
  * the background closer: deadline windows close with NO caller in
    flight (the cooperative-clock caveat retired), and the sync front's
    residual caveat fix — ``result()`` on an already-done handle drives
    the deadline clock for other windows;
  * per-tenant admission control: fail-fast and queue-mode quotas,
    byte attribution on the memory pools, per-tenant report sections;
  * adaptive windowing: bursty vs trickle arrival traces move the
    window parameters in the right direction, and the p99 SLO bounds
    wait + execution on the injectable clock;
  * the async_close fault point: a crashed closer task restarts and
    every pending handle still resolves; the seeded soak extends the
    PR 6 property (every handle resolves, successes bit-identical to
    fault-free) to the async front.

Tests drive their own event loops via ``asyncio.run`` so the module
needs no pytest plugin; the CI concurrency job additionally installs
pytest-asyncio and runs the plugin-marked variants.
"""
import asyncio
import os

import numpy as np
import pytest

from repro.core.faults import FAULT_POINTS, FaultConfig
from repro.core.telemetry import MetricsRegistry, labeled_key
from repro.relational import (AdmissionError, AsyncConfig,
                              AdaptiveWindowPolicy, AsyncQueryService,
                              I32, MemoryConfig, QueryError,
                              QueryService, Relation, Schema, Session,
                              SessionConfig, TenantQuota, expr as E,
                              logical as L, make_storage)

try:
    import pytest_asyncio  # noqa: F401
    HAVE_PYTEST_ASYNCIO = True
except ImportError:
    HAVE_PYTEST_ASYNCIO = False

# the CI concurrency job sweeps this over a small matrix
FAULT_SEED = int(os.environ.get("FAULT_SEED", "0"))

S = Schema.of(("a", I32), ("b", I32), ("c", I32))
NROWS = 2000


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _mk_session(budget=1 << 24, *, config=None) -> Session:
    rng = np.random.default_rng(9)
    cols = {c: rng.integers(0, 100, NROWS).astype(np.int32)
            for c in ("a", "b", "c")}
    if config is None:
        config = SessionConfig(memory=MemoryConfig(budget_bytes=budget))
    sess = Session.from_config(config)
    st, _ = make_storage("t", S, NROWS, "columnar", cols=cols)
    sess.register(st)
    return sess


def _queries(sess):
    t = lambda: sess.table("t")  # noqa: E731
    return [
        t().filter(E.cmp("a", ">", 50)).project("a", "b"),
        t().filter(E.and_(E.cmp("a", ">", 50), E.cmp("b", "<", 40)))
           .project("a", "b"),
        t().filter(E.and_(E.cmp("a", ">", 50), E.cmp("c", ">", 20)))
           .project("a", "c"),
        t().filter(E.cmp("b", "<", 70)).project("b", "c"),
        t().filter(E.and_(E.cmp("b", "<", 70), E.cmp("c", ">", 10)))
           .project("b", "c"),
        t().filter(E.cmp("c", ">", 35)).project("a", "b", "c"),
    ]


def _tables_bit_identical(ta, tb):
    assert ta.nrows == tb.nrows
    assert ta.schema.names == tb.schema.names
    for n in ta.schema.names:
        assert np.array_equal(np.asarray(ta.columns[n])[: ta.nrows],
                              np.asarray(tb.columns[n])[: tb.nrows]), n


def run(coro):
    return asyncio.run(coro)


# ---------------------------------------------------------------------------
# submit / await round trips
# ---------------------------------------------------------------------------
class TestAsyncSubmission:
    def test_submit_await_matches_sync_reference(self):
        ref = _mk_session()
        base = ref.run_batch(_queries(ref)[:1])

        async def go():
            sess = _mk_session()
            async with AsyncQueryService(
                    sess, config=AsyncConfig(max_batch=1)) as svc:
                h = await svc.submit(_queries(sess)[0])
                t1 = await h
                t2 = await h.result()     # both await forms work
            return t1, t2

        t1, t2 = run(go())
        _tables_bit_identical(t1, base.results[0].table)
        _tables_bit_identical(t2, base.results[0].table)

    def test_concurrent_submitters_share_one_window(self):
        async def go():
            sess = _mk_session()
            qs = _queries(sess)
            async with AsyncQueryService(
                    sess, config=AsyncConfig(max_batch=6)) as svc:
                async def client(q):
                    h = await svc.submit(q)
                    return h, await h

                done = await asyncio.gather(*(client(q) for q in qs))
            sizes = [h.explain()["window_size"] for h, _ in done]
            closed = sess.telemetry().registry.value("windows.closed")
            return sizes, closed

        sizes, closed = run(go())
        assert sizes == [6] * 6          # one shared window
        assert closed == 1

    def test_async_vs_sync_bit_identical_on_same_plan_set(self):
        sync_sess = _mk_session()
        base = sync_sess.run_batch(_queries(sync_sess))

        async def go():
            sess = _mk_session()
            async with AsyncQueryService(
                    sess, config=AsyncConfig(max_batch=6)) as svc:
                hs = [await svc.submit(q) for q in _queries(sess)]
                return await asyncio.gather(*hs)

        tables = run(go())
        for t, r0 in zip(tables, base.results):
            _tables_bit_identical(t, r0.table)

    def test_failed_query_raises_on_await_sibling_completes(self):
        async def go():
            sess = _mk_session()
            async with AsyncQueryService(
                    sess, config=AsyncConfig(max_batch=2)) as svc:
                ghost = Relation(L.scan("ghost", S, "columnar"), sess)
                h_bad = await svc.submit(ghost)
                h_ok = await svc.submit(_queries(sess)[0])
                t = await h_ok
                with pytest.raises(Exception):
                    await h_bad
                assert h_bad.failed
                assert isinstance(h_bad.error, QueryError)
                assert not h_ok.failed
                return t

        assert run(go()).nrows > 0


# ---------------------------------------------------------------------------
# the background closer
# ---------------------------------------------------------------------------
class TestBackgroundCloser:
    def test_deadline_closes_with_no_caller_in_flight(self):
        """The retired caveat: nobody calls submit/poll/result — the
        closer task alone fires the deadline."""
        async def go():
            sess = _mk_session()
            async with AsyncQueryService(
                    sess,
                    config=AsyncConfig(max_batch=64,
                                       max_wait_s=0.05)) as svc:
                h = await svc.submit(_queries(sess)[0])
                # no flush, no poll: only the background closer can
                # resolve this within the timeout
                return await asyncio.wait_for(h.result(), timeout=10)

        assert run(go()).nrows > 0

    def test_flush_expired_and_poll_are_thin_shims(self):
        async def go():
            sess = _mk_session()
            async with AsyncQueryService(
                    sess,
                    config=AsyncConfig(max_batch=64,
                                       max_wait_s=30.0)) as svc:
                h = await svc.submit(_queries(sess)[0])
                assert svc.flush_expired() is None
                assert svc.poll() is False
                assert not h.done          # nothing closed the window
                await svc.flush()
                await svc.drain()
                assert h.done

        run(go())

    def test_sync_done_result_closes_other_expired_window(self):
        """Satellite fix on the SYNC front: ``result()`` on an
        already-resolved handle drives the cooperative deadline clock,
        so an expired window closes without an unrelated submit."""
        sess = _mk_session()
        clock = FakeClock()
        svc = QueryService(sess, max_batch=10, max_wait_s=1.0,
                           clock=clock)
        qs = _queries(sess)
        a = svc.submit(qs[0])
        svc.flush()
        assert a.done
        b = svc.submit(qs[1])              # opens a new deadline window
        clock.advance(2.0)                 # ... which expires
        a.result()                         # done handle still drives it
        assert b.done
        _tables_bit_identical(a.result(), a.result())


# ---------------------------------------------------------------------------
# admission control + tenants
# ---------------------------------------------------------------------------
class TestAdmissionControl:
    def test_inflight_quota_fail_fast(self):
        async def go():
            sess = _mk_session()
            cfg = AsyncConfig(
                max_batch=64, max_wait_s=30.0,
                quotas={"acme": TenantQuota(max_inflight=1,
                                            on_over="fail")})
            async with AsyncQueryService(sess, config=cfg) as svc:
                qs = _queries(sess)
                h = await svc.submit(qs[0], tenant="acme")
                with pytest.raises(AdmissionError):
                    await svc.submit(qs[1], tenant="acme")
                # other tenants (and untenanted work) are unaffected
                await svc.submit(qs[2], tenant="other")
                await svc.submit(qs[3])
                await svc.flush()
                await svc.drain()
                assert h.done
            reg = sess.telemetry().registry
            assert reg.value("admission.rejected",
                             labels={"tenant": "acme"}) == 1

        run(go())

    def test_inflight_quota_queue_mode_waits_then_admits(self):
        async def go():
            sess = _mk_session()
            cfg = AsyncConfig(
                max_batch=1,    # every submission closes its window
                quotas={"acme": TenantQuota(max_inflight=1,
                                            on_over="queue")})
            async with AsyncQueryService(sess, config=cfg) as svc:
                qs = _queries(sess)

                async def client(q):
                    h = await svc.submit(q, tenant="acme")
                    return await h

                tables = await asyncio.wait_for(
                    asyncio.gather(*(client(q) for q in qs[:3])),
                    timeout=30)
            reg = sess.telemetry().registry
            return tables, reg

        tables, reg = run(go())
        assert len(tables) == 3 and all(t.nrows >= 0 for t in tables)
        assert reg.value("admission.admitted",
                         labels={"tenant": "acme"}) == 3
        # at least one submission had to wait for an in-flight slot
        assert reg.value("admission.queued",
                         labels={"tenant": "acme"}) >= 1

    def test_byte_attribution_and_tenant_report(self):
        async def go():
            sess = _mk_session()
            async with AsyncQueryService(
                    sess, config=AsyncConfig(max_batch=2)) as svc:
                qs = _queries(sess)
                ha = [await svc.submit(q, tenant="acme")
                      for q in qs[:2]]
                hb = [await svc.submit(q, tenant="blue")
                      for q in qs[3:5]]
                await asyncio.gather(*(ha + hb))
                report = svc.metrics_report()
            usage = sess.memory.owner_usage()
            return report, usage

        report, usage = run(go())
        # execution stamped live pool bytes to the submitting tenants
        assert "acme" in usage and sum(usage["acme"].values()) > 0
        tenants = report["tenants"]
        for t in ("acme", "blue"):
            assert tenants[t]["queries.submitted"] == 2
            assert tenants[t]["queries.succeeded"] == 2
            assert tenants[t]["bytes_total"] > 0
            assert tenants[t]["latency"]["count"] == 2
        # labeled snapshot keys use the canonical rendered form
        snap = report["registry"]
        assert "queries.submitted{tenant=acme}" in snap["counters"]

    def test_bytes_quota_fail_fast_when_nothing_inflight(self):
        """Resident attributed bytes over max_bytes with zero in-flight
        queries can never be freed by a completion — queue mode must
        reject instead of deadlocking."""
        async def go():
            sess = _mk_session()
            cfg = AsyncConfig(
                max_batch=1,
                quotas={"acme": TenantQuota(max_bytes=1)})
            async with AsyncQueryService(sess, config=cfg) as svc:
                qs = _queries(sess)
                h = await svc.submit(qs[0], tenant="acme")
                await h                        # resident bytes now > 1
                assert sess.memory.owner_bytes("acme") > 1
                with pytest.raises(AdmissionError):
                    await svc.submit(qs[1], tenant="acme")

        run(go())


# ---------------------------------------------------------------------------
# adaptive windowing
# ---------------------------------------------------------------------------
def _policy(sess, clock, **cfg_kw):
    cfg = AsyncConfig(adaptive=True, slo_p99_s=0.5, min_batch=1,
                      max_batch_cap=64, exec_default_s=0.05, **cfg_kw)
    return AdaptiveWindowPolicy(sess, cfg, clock=clock)


class TestAdaptiveWindowing:
    def test_bursty_vs_trickle_directionality(self):
        """A bursty family earns a bigger batch target than a trickle
        family; the trickle degenerates to close-immediately."""
        sess = _mk_session()
        clock = FakeClock()
        pol = _policy(sess, clock)
        for _ in range(50):                  # 1 kHz burst
            clock.advance(0.001)
            pol.observe_arrival("burst", now=clock())
        for _ in range(10):                  # one every 2 s
            clock.advance(2.0)
            pol.observe_arrival("trickle", now=clock())
        burst = pol.decide("burst")
        trickle = pol.decide("trickle")
        assert burst.max_batch > trickle.max_batch
        assert burst.max_batch > 8           # real sharing harvested
        assert trickle.max_batch == 1        # latency-optimal
        assert burst.predicted_saving_s > trickle.predicted_saving_s

    def test_p99_slo_respected_on_injectable_clock(self):
        """wait + exec_p99 <= slo by construction, for any observed
        execution-time distribution."""
        sess = _mk_session()
        clock = FakeClock()
        pol = _policy(sess, clock)
        reg = sess.telemetry().registry
        for v in (0.01, 0.02, 0.05, 0.3):    # window exec observations
            reg.observe("window.seconds", v)
        for _ in range(50):
            clock.advance(0.001)
            pol.observe_arrival("burst", now=clock())
        p = pol.decide("burst")
        exec99 = reg.histogram("window.seconds").percentile(0.99)
        assert p.max_wait_s + exec99 <= 0.5 + 1e-9
        assert p.wait_budget_s == pytest.approx(
            max(0.0, 0.5 - exec99))

    def test_slo_already_blown_collapses_to_min_batch(self):
        sess = _mk_session()
        clock = FakeClock()
        pol = _policy(sess, clock)
        reg = sess.telemetry().registry
        reg.observe("window.seconds", 10.0)  # exec alone exceeds SLO
        for _ in range(50):
            clock.advance(0.001)
            pol.observe_arrival("burst", now=clock())
        p = pol.decide("burst")
        assert p.max_batch == 1
        assert p.max_wait_s == 0.0           # close immediately

    def test_fixed_mode_uses_configured_knobs(self):
        sess = _mk_session()
        cfg = AsyncConfig(adaptive=False, max_batch=7, max_wait_s=1.5)
        pol = AdaptiveWindowPolicy(sess, cfg, clock=FakeClock())
        p = pol.decide("any")
        assert (p.max_batch, p.max_wait_s) == (7, 1.5)

    def test_adaptive_end_to_end_records_metrics(self):
        async def go():
            sess = _mk_session()
            cfg = AsyncConfig(adaptive=True, slo_p99_s=5.0,
                              max_batch_cap=8, exec_default_s=0.01)
            async with AsyncQueryService(sess, config=cfg) as svc:
                qs = _queries(sess)
                for _ in range(3):
                    hs = [await svc.submit(q) for q in qs]
                    await asyncio.gather(*hs)
                    await svc.flush()
                    await svc.drain()
            reg = sess.telemetry().registry
            return reg

        reg = run(go())
        assert reg.histogram("window.adaptive.batch").count > 0
        assert reg.histogram("window.adaptive.wait_s").count > 0
        assert reg.ewma("window.adaptive.predicted_saving_s").n > 0
        assert reg.ewma("window.adaptive.realized_saving_s").n > 0


# ---------------------------------------------------------------------------
# labels (snapshot-format pin, satellite 2)
# ---------------------------------------------------------------------------
class TestMetricLabels:
    def test_labeled_key_rendering_is_pinned(self):
        assert labeled_key("queries.submitted") == "queries.submitted"
        assert labeled_key("queries.submitted", {"tenant": "acme"}) \
            == "queries.submitted{tenant=acme}"
        # label keys sort for a canonical rendering
        assert labeled_key("m", {"b": "2", "a": "1"}) == "m{a=1,b=2}"

    def test_registry_labeled_series(self):
        reg = MetricsRegistry()
        reg.inc("queries.submitted")
        reg.inc("queries.submitted", labels={"tenant": "acme"})
        reg.inc("queries.submitted", 2, labels={"tenant": "blue"})
        snap = reg.snapshot()
        assert snap["counters"]["queries.submitted"] == 1
        assert snap["counters"]["queries.submitted{tenant=acme}"] == 1
        assert snap["counters"]["queries.submitted{tenant=blue}"] == 2
        assert reg.value("queries.submitted",
                         labels={"tenant": "blue"}) == 2
        series = dict(
            (labels["tenant"], key)
            for labels, key in reg.series("queries.submitted"))
        assert series == {
            "acme": "queries.submitted{tenant=acme}",
            "blue": "queries.submitted{tenant=blue}",
        }
        # histograms and ewmas label identically
        reg.observe("latency.tenant", 0.5, labels={"tenant": "acme"})
        assert reg.histogram(
            "latency.tenant", labels={"tenant": "acme"}).count == 1


# ---------------------------------------------------------------------------
# async_close fault point + the async soak
# ---------------------------------------------------------------------------
def _fault_cfg(budget=1 << 24, **fault_kw) -> SessionConfig:
    return SessionConfig(
        memory=MemoryConfig(budget_bytes=budget)
    ).with_faults(FaultConfig(**fault_kw))


class TestAsyncCloseFault:
    def test_crashed_closer_restarts_and_handles_resolve(self):
        async def go():
            sess = _mk_session(config=_fault_cfg(
                seed=FAULT_SEED, schedule={"async_close": (0,)}))
            async with AsyncQueryService(
                    sess,
                    config=AsyncConfig(max_batch=64,
                                       max_wait_s=0.02)) as svc:
                h = await svc.submit(_queries(sess)[0])
                # first deadline pass fires the fault and crashes the
                # closer; the supervisor restarts it and the still-due
                # window closes on the next pass
                t = await asyncio.wait_for(h.result(), timeout=10)
                return t, svc.closer_restarts, sess

        t, restarts, sess = run(go())
        assert t.nrows > 0
        assert restarts >= 1
        reg = sess.telemetry().registry
        assert reg.value("async.closer_restarts") >= 1
        assert sess.fault_injector.invocations("async_close") >= 1

    def test_soak_with_faults_including_async_close(self):
        """The PR 6 soak property, extended to the async front: under
        seeded faults at every point INCLUDING async_close, every async
        handle resolves and every success is bit-identical to a
        fault-free reference of the same window."""
        rates = {p: 0.05 for p in FAULT_POINTS}
        rates["window_close"] = 0.02
        rates["async_close"] = 0.5     # exercise the closer hard

        async def go():
            faulty = _mk_session(config=_fault_cfg(
                1 << 15, seed=FAULT_SEED, rates=rates))
            ref = _mk_session(budget=1 << 15)
            import random
            rng = random.Random(FAULT_SEED)
            n_ok = n_failed = 0
            async with AsyncQueryService(
                    faulty,
                    config=AsyncConfig(max_batch=64,
                                       max_wait_s=0.01)) as svc:
                for w in range(25):
                    idxs = rng.choices(range(6), k=rng.randint(1, 3))
                    pool_f, pool_r = _queries(faulty), _queries(ref)
                    hs = [await svc.submit(pool_f[i]) for i in idxs]
                    # deadline-close only: every window exercises the
                    # async_close fault point
                    done = await asyncio.wait_for(
                        asyncio.gather(*(h.result() for h in hs),
                                       return_exceptions=True),
                        timeout=60)
                    base = ref.run_batch([pool_r[i] for i in idxs])
                    for h, t, r0 in zip(hs, done, base.results):
                        assert h.done, f"window {w}: unresolved handle"
                        if isinstance(t, BaseException):
                            n_failed += 1
                            assert h.failed
                        else:
                            n_ok += 1
                            _tables_bit_identical(t, r0.table)
                    violations = faulty.memory.audit()
                    assert violations == [], f"window {w}: {violations}"
            return n_ok, n_failed, svc, faulty

        n_ok, n_failed, svc, faulty = run(go())
        assert n_ok > 0, "soak never completed a query"
        inj = faulty.fault_injector
        assert inj.invocations("async_close") > 0
        # at rate 0.5 over 25 deadline windows the closer crashed at
        # least once for any realistic seed stream
        assert svc.closer_restarts >= 1


if HAVE_PYTEST_ASYNCIO:
    # the CI concurrency job installs pytest-asyncio; this variant
    # exercises the front under the plugin's own loop management
    @pytest.mark.asyncio
    async def test_plugin_loop_submit_await():
        sess = _mk_session()
        async with AsyncQueryService(
                sess, config=AsyncConfig(max_batch=2)) as svc:
            qs = _queries(sess)
            h1 = await svc.submit(qs[0])
            h2 = await svc.submit(qs[1])
            t1, t2 = await asyncio.gather(h1.result(), h2.result())
        assert t1.nrows > 0 and t2.nrows > 0
