"""Training loop, optimizer, checkpointing, fault tolerance."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Pipeline, make_batch
from repro.train.optimizer import (OptConfig, adamw_update, init_opt_state,
                                   lr_schedule)
from repro.train.trainer import PreemptionError, TrainerConfig, train


class TestOptimizer:
    def test_lr_schedule_warmup_and_decay(self):
        cfg = OptConfig(peak_lr=1.0, warmup_steps=10, decay_steps=100)
        assert float(lr_schedule(jnp.int32(5), cfg)) == pytest.approx(0.5)
        assert float(lr_schedule(jnp.int32(10), cfg)) == pytest.approx(1.0)
        late = float(lr_schedule(jnp.int32(100), cfg))
        assert late == pytest.approx(cfg.peak_lr * cfg.min_lr_ratio,
                                     rel=1e-3)

    def test_adamw_moves_params_against_gradient(self):
        params = {"w": jnp.ones((4,))}
        grads = {"w": jnp.ones((4,))}
        state = init_opt_state(params)
        cfg = OptConfig(peak_lr=0.1, warmup_steps=0, decay_steps=10,
                        weight_decay=0.0)
        new, state, m = adamw_update(params, grads, state, cfg)
        assert float(new["w"][0]) < 1.0
        assert int(state["step"]) == 1

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros((4,))}
        grads = {"w": jnp.full((4,), 1e6)}
        state = init_opt_state(params)
        cfg = OptConfig(peak_lr=0.1, warmup_steps=0, grad_clip=1.0,
                        weight_decay=0.0)
        new, _, metrics = adamw_update(params, grads, state, cfg)
        assert np.isfinite(np.asarray(new["w"])).all()
        assert float(metrics["grad_norm"]) > 1e5


class TestDataPipeline:
    def test_batches_deterministic_in_step(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4)
        b1, b2 = make_batch(cfg, 7), make_batch(cfg, 7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = make_batch(cfg, 8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_prefetch_pipeline_order(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=2)
        pipe = Pipeline(cfg, start_step=5)
        steps = [next(pipe)[0] for _ in range(4)]
        pipe.close()
        assert steps == [5, 6, 7, 8]

    def test_host_sharding_disjoint(self):
        a = DataConfig(vocab_size=100, seq_len=16, global_batch=8,
                       process_index=0, process_count=2)
        b = DataConfig(vocab_size=100, seq_len=16, global_batch=8,
                       process_index=1, process_count=2)
        ba, bb = make_batch(a, 0), make_batch(b, 0)
        assert ba["tokens"].shape[0] == 4
        assert not np.array_equal(ba["tokens"], bb["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=2)
        b = make_batch(cfg, 0)
        np.testing.assert_array_equal(b["labels"][:, :-1],
                                      b["tokens"][:, 1:])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((2, 3))}}
        mgr.save(5, tree, blocking=True)
        step, restored = mgr.restore(tree)
        assert step == 5
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"a": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree, blocking=True)
        assert mgr._steps() == [3, 4]

    def test_crash_leaves_no_partial_commit(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        tree = {"a": jnp.zeros(4)}
        mgr.save(1, tree, blocking=True)
        # simulate a crashed writer: stale tmp dir
        os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
        assert mgr.latest_step() == 1
        mgr.save(3, tree, blocking=True)     # GC removes stale tmp
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))

    def test_restore_latest_of_many(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=5)
        for s in (10, 20, 30):
            mgr.save(s, {"a": jnp.full(2, float(s))}, blocking=True)
        step, tree = mgr.restore({"a": jnp.zeros(2)})
        assert step == 30 and float(tree["a"][0]) == 30.0


class TestFaultTolerance:
    def _cfgs(self, ckpt_dir, fail_after=None, steps=12):
        cfg = get_config("gemma3-1b-smoke")
        data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=2)
        opt = OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=12)
        t = TrainerConfig(total_steps=steps, ckpt_every=4,
                          ckpt_dir=ckpt_dir, log_every=2,
                          fail_after_step=fail_after)
        return cfg, data, opt, t

    def test_preemption_resume_is_bitwise(self, tmp_path):
        d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
        cfg, data, opt, t_full = self._cfgs(d1)
        r_full = train(cfg, data, opt, t_full)

        cfg, data, opt, t_fail = self._cfgs(d2, fail_after=8)
        with pytest.raises(PreemptionError):
            train(cfg, data, opt, t_fail)
        cfg, data, opt, t_resume = self._cfgs(d2)
        r_res = train(cfg, data, opt, t_resume)
        assert r_res.resumed_from == 8
        for a, b in zip(jax.tree.leaves(r_full.params),
                        jax.tree.leaves(r_res.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_loss_decreases_over_training(self, tmp_path):
        cfg = get_config("gemma3-1b-smoke")
        from dataclasses import replace

        cfg = replace(cfg, vocab_size=128)
        data = DataConfig(vocab_size=64, seq_len=32, global_batch=4)
        opt = OptConfig(peak_lr=5e-3, warmup_steps=5, decay_steps=60)
        t = TrainerConfig(total_steps=60, ckpt_every=1000,
                          ckpt_dir=str(tmp_path), log_every=5)
        r = train(cfg, data, opt, t)
        first = r.metrics_log[0]["loss"]
        last = min(m["loss"] for m in r.metrics_log[-3:])
        assert last < first - 0.3, (first, last)


class TestGradCompression:
    def test_error_feedback_tracks_fp32(self):
        """Compressed-path updates stay close to fp32 across steps."""
        from repro.train.train_step import make_train_step
        from repro.models.model import init_params

        cfg = get_config("gemma3-1b-smoke")
        params = init_params(cfg, 0)

        # single-device functional check of the quantize+feedback math
        rng = np.random.default_rng(0)
        g_true = rng.standard_normal(1000).astype(np.float32) * 1e-3
        err = np.zeros_like(g_true)
        acc_fp32, acc_comp = np.zeros_like(g_true), np.zeros_like(g_true)
        for _ in range(50):
            g = g_true + rng.standard_normal(1000).astype(np.float32) * 1e-4
            acc_fp32 += g
            total = g + err
            g16 = total.astype(jnp.bfloat16)
            err = total - np.asarray(g16, np.float32)
            acc_comp += np.asarray(g16, np.float32)
        # error feedback keeps the cumulative difference at one-step
        # quantization scale, it does not accumulate
        assert np.abs(acc_fp32 - acc_comp).max() < 1e-4
