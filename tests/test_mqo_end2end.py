"""End-to-end MQO invariants.

The system's core guarantee: for ANY batch of queries and ANY memory
budget, the MQO-rewritten batch produces EXACTLY the same result
multisets as independent execution — worksharing must never change
semantics.  Property-tested over random schemas/predicates/workloads.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from conftest import build_session, hr_queries
from repro.relational import (I32, STR, F32, Schema, Session, expr as E,
                              logical as L, make_storage,
                              SessionConfig)


def _assert_batches_equal(base, opt):
    assert len(base.results) == len(opt.results)
    for i, (b, o) in enumerate(zip(base.results, opt.results)):
        assert b.table.row_multiset() == o.table.row_multiset(), \
            f"query {i} diverged under MQO"


class TestRunningExample:
    """The paper's §3 example: 3 HR queries, 4 SEs (ψ1..ψ4)."""

    def test_identifies_paper_ses(self, hr_session):
        res = hr_session.run_batch(hr_queries(hr_session), mqo=True)
        r = res.mqo.report
        assert r.n_ses >= 4      # ψ1..ψ4 (plus scan-level SEs)
        assert r.n_selected >= 1
        assert r.selected_weight <= r.budget

    @pytest.mark.parametrize("budget_kb", [1, 64, 1024, 1 << 20])
    def test_results_identical_any_budget(self, hr_session, budget_kb):
        qs = hr_queries(hr_session)
        base = hr_session.run_batch(qs, mqo=False)
        opt = hr_session.run_batch(qs, mqo=True,
                                   budget_bytes=budget_kb * 1024)
        _assert_batches_equal(base, opt)

    def test_csv_format_identical(self, hr_data):
        sess = build_session(hr_data, fmt="csv")
        qs = hr_queries(sess)
        base = sess.run_batch(qs, mqo=False)
        opt = sess.run_batch(qs, mqo=True)
        _assert_batches_equal(base, opt)

    def test_fullcache_baseline_identical(self, hr_session):
        qs = hr_queries(hr_session)
        base = hr_session.run_batch(qs, mqo=False)
        fc = hr_session.run_batch_fullcache(qs)
        _assert_batches_equal(base, fc)

    def test_budget_respected(self, hr_session):
        res = hr_session.run_batch(hr_queries(hr_session), mqo=True,
                                   budget_bytes=256 * 1024)
        assert res.mqo.report.selected_weight <= 256 * 1024


class TestExtractionSafety:
    """Divergent filters below aggregates/limits must not be merged."""

    def test_aggregate_above_divergent_filters(self, hr_session):
        sal = hr_session.table("salaries")
        q1 = (sal.filter(E.cmp("salary", ">", 50_000))
              .groupby("from_year").agg(("n", "count", "")))
        q2 = (sal.filter(E.cmp("salary", ">", 20_000))
              .groupby("from_year").agg(("n", "count", "")))
        base = hr_session.run_batch([q1, q2], mqo=False)
        opt = hr_session.run_batch([q1, q2], mqo=True)
        _assert_batches_equal(base, opt)

    def test_equal_aggregates_do_share(self, hr_session):
        sal = hr_session.table("salaries")

        def q():
            return (sal.filter(E.cmp("salary", ">", 50_000))
                    .groupby("from_year").agg(("n", "count", "")))

        res = hr_session.run_batch([q(), q()], mqo=True)
        assert res.mqo.report.n_selected >= 1
        _assert_batches_equal(hr_session.run_batch([q(), q()], mqo=False),
                              res)

    def test_limit_above_divergent_filters(self, hr_session):
        sal = hr_session.table("salaries")
        q1 = sal.filter(E.cmp("salary", ">", 60_000)).sort("salary").limit(5)
        q2 = sal.filter(E.cmp("salary", ">", 10_000)).sort("salary").limit(5)
        base = hr_session.run_batch([q1, q2], mqo=False)
        opt = hr_session.run_batch([q1, q2], mqo=True)
        # limits have unspecified tie order; counts must match and each
        # result must still satisfy its own predicate
        for b, o in zip(base.results, opt.results):
            assert b.table.nrows == o.table.nrows


# ---------------------------------------------------------------------------
# property-based workload fuzzing
# ---------------------------------------------------------------------------
_COLS = ["c0", "c1", "c2"]


@st.composite
def _pred(draw, depth=0):
    kind = draw(st.sampled_from(
        ["cmp", "cmp", "cmp", "and", "or"] if depth < 2 else ["cmp"]))
    if kind == "cmp":
        return E.cmp(draw(st.sampled_from(_COLS)),
                     draw(st.sampled_from(["<", "<=", ">", ">=", "==",
                                           "!="])),
                     int(draw(st.integers(0, 60))))
    parts = draw(st.lists(_pred(depth=depth + 1), min_size=2, max_size=3))
    return (E.and_ if kind == "and" else E.or_)(*parts)


@st.composite
def _query(draw):
    base = L.scan("ft", _FUZZ_SCHEMA)
    q = base.filter(draw(_pred()))
    if draw(st.booleans()):
        cols = draw(st.lists(st.sampled_from(_COLS + ["c3"]), min_size=1,
                             max_size=4, unique=True))
        q = q.project(*cols)
    shape = draw(st.sampled_from(["plain", "plain", "agg", "sort", "join"]))
    if shape == "agg" and q.schema.has("c0"):
        aggs = [("n", "count", "")]
        if q.schema.has("c3"):
            aggs.append(("s3", "sum", "c3"))
        q = q.groupby("c0").agg(*aggs)
    elif shape == "sort" and q.schema.has("c1"):
        q = q.sort("c1", desc=draw(st.booleans()))
    elif shape == "join" and q.schema.has("c0"):
        other = L.scan("dim", _DIM_SCHEMA).filter(
            E.cmp("d1", draw(st.sampled_from([">", "<"])),
                  int(draw(st.integers(0, 60)))))
        q = q.join(other, "c0", "d0")
    return q


_FUZZ_SCHEMA = Schema.of(("c0", I32), ("c1", I32), ("c2", I32),
                         ("c3", I32))
_DIM_SCHEMA = Schema.of(("d0", I32), ("d1", I32))


@pytest.fixture(scope="module")
def fuzz_session():
    rng = np.random.default_rng(42)
    n, nd = 800, 64
    fact = {c: rng.integers(0, 64, n).astype(np.int32) for c in
            ["c0", "c1", "c2", "c3"]}
    dim = {"d0": np.arange(nd, dtype=np.int32),
           "d1": rng.integers(0, 64, nd).astype(np.int32)}
    sess = Session.from_config(
        SessionConfig.from_legacy_kwargs(budget_bytes=1 << 24))
    st1, _ = make_storage("ft", _FUZZ_SCHEMA, n, "columnar", cols=fact)
    st2, _ = make_storage("dim", _DIM_SCHEMA, nd, "columnar", cols=dim)
    sess.register(st1)
    sess.register(st2)
    return sess


class TestPropertyMQONeverChangesResults:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(queries=st.lists(_query(), min_size=2, max_size=5),
           budget_log2=st.integers(10, 24))
    def test_rewritten_equals_baseline(self, fuzz_session, queries,
                                       budget_log2):
        base = fuzz_session.run_batch(queries, mqo=False)
        opt = fuzz_session.run_batch(queries, mqo=True,
                                     budget_bytes=1 << budget_log2)
        for i, (b, o) in enumerate(zip(base.results, opt.results)):
            assert b.table.row_multiset() == o.table.row_multiset(), \
                f"query {i} diverged (budget=2^{budget_log2})\n" + \
                L.explain(queries[i])
        assert opt.mqo.report.selected_weight <= (1 << budget_log2)


class TestPropertyServiceEqualsOneShot:
    """ISSUE 3: the online QueryService is the same machinery as
    run_batch — for ANY workload and ANY window size, submitting the
    queries one at a time and letting windows close must produce the
    same result per query as the legacy one-shot batch."""

    @settings(max_examples=15, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(queries=st.lists(_query(), min_size=2, max_size=6),
           max_batch=st.integers(1, 6))
    def test_windowed_submit_equals_run_batch(self, fuzz_session,
                                              queries, max_batch):
        from repro.relational import QueryService

        base = fuzz_session.run_batch(queries, mqo=True)
        svc = QueryService(fuzz_session, max_batch=max_batch)
        handles = [svc.submit(q) for q in queries]
        svc.flush()                       # close the trailing window
        for i, (b, h) in enumerate(zip(base.results, handles)):
            assert h.done
            assert b.table.row_multiset() == h.result().row_multiset(), \
                f"query {i} diverged (window={max_batch})\n" + \
                L.explain(queries[i])

    @settings(max_examples=10, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(queries=st.lists(_query(), min_size=2, max_size=4))
    def test_pre_closed_window_bit_identical(self, fuzz_session, queries):
        """submit-then-flush in one window vs run_batch on the same
        plans: exactly equal arrays, not just equal multisets."""
        from repro.relational import QueryService

        batch = fuzz_session.run_batch(queries, mqo=True)
        svc = QueryService(fuzz_session, max_batch=len(queries) + 1)
        handles = [svc.submit(q) for q in queries]
        svc.flush()
        for qr, h in zip(batch.results, handles):
            ta, tb = qr.table, h.result()
            assert ta.nrows == tb.nrows
            assert ta.schema.names == tb.schema.names
            for n in ta.schema.names:
                assert np.array_equal(
                    np.asarray(ta.columns[n])[: ta.nrows],
                    np.asarray(tb.columns[n])[: tb.nrows]), n
