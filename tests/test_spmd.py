"""SPMD tests on a small multi-device host mesh (subprocess-isolated so
the main test process keeps its single-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardedTrainStep:
    def test_train_step_matches_single_device(self):
        out = _run("""
            import numpy as np, jax, jax.numpy as jnp
            from repro.configs import get_config
            from repro.launch.mesh import make_test_mesh
            from repro.launch.sharding import (param_shardings,
                batch_shardings, opt_state_shardings)
            from repro.models.model import (init_params, model_specs,
                input_specs, ShapeCell)
            from repro.models.common import abstract_params
            from repro.train.optimizer import OptConfig
            from repro.train.train_step import (init_train_state,
                make_train_step)

            cfg = get_config("gemma3-1b-smoke")
            params = init_params(cfg, 0)
            opt = init_train_state(cfg, params)
            rng = np.random.default_rng(0)
            B, T = 8, 32
            batch = {
              "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                 (B, T)), jnp.int32),
              "labels": jnp.asarray(rng.integers(0, cfg.vocab_size,
                                                 (B, T)), jnp.int32),
              "mask": jnp.ones((B, T), jnp.float32)}
            step = make_train_step(cfg, OptConfig(peak_lr=1e-3))

            # single device reference
            p1, o1, m1 = jax.jit(step)(params, opt, batch)

            # sharded
            mesh = make_test_mesh((4, 2), ("data", "model"))
            specs = model_specs(cfg)
            p_sh = param_shardings(specs, cfg, mesh)
            o_sh = opt_state_shardings(p_sh, mesh)
            cell = ShapeCell("t", T, B, "train")
            b_sh = batch_shardings(cfg, cell, mesh, batch)
            params_s = jax.device_put(params, p_sh)
            opt_s = jax.device_put(opt, o_sh)
            batch_s = jax.device_put(batch, b_sh)
            p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh))(
                params_s, opt_s, batch_s)

            print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
            assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=2e-5)
            print("SPMD_OK")
        """)
        assert "SPMD_OK" in out

    def test_moe_expert_parallel_matches(self):
        out = _run("""
            import numpy as np, jax, jax.numpy as jnp
            from dataclasses import replace
            from repro.configs import get_config
            from repro.launch.mesh import make_test_mesh
            from repro.launch.sharding import param_shardings, batch_shardings
            from repro.models.model import (init_params, model_specs,
                forward, ShapeCell)

            cfg = replace(get_config("llama4-scout-17b-a16e-smoke"),
                          capacity_factor=8.0)
            params = init_params(cfg, 0)
            rng = np.random.default_rng(0)
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 16)),
                               jnp.int32)
            ref = forward(params, toks, cfg)

            mesh = make_test_mesh((2, 4), ("data", "model"))
            specs = model_specs(cfg)
            p_sh = param_shardings(specs, cfg, mesh)
            params_s = jax.device_put(params, p_sh)
            fn = jax.jit(lambda p, t: forward(p, t, cfg),
                         in_shardings=(p_sh, None))
            got = fn(params_s, toks)
            np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                       atol=3e-4)
            print("MOE_EP_OK")
        """)
        assert "MOE_EP_OK" in out


class TestShardedRelational:
    def test_row_sharded_query_matches(self):
        out = _run("""
            import numpy as np, jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_test_mesh
            from repro.relational import Session, SessionConfig, expr as E, make_storage
            from repro.relational.datagen import (generate_columns,
                synthetic_schema)

            schema = synthetic_schema(n_int=3, n_dbl=1, n_str=1)
            cols = generate_columns(schema, 4096, seed=0)
            mesh = make_test_mesh((8,), ("data",))
            sharding = NamedSharding(mesh, P("data"))

            plain = Session.from_config(
                SessionConfig.from_legacy_kwargs(budget_bytes=1 << 24))
            st, _ = make_storage("t", schema, 4096, "columnar", cols=cols)
            plain.register(st, columnar_for_stats=cols)
            sharded = Session.from_config(SessionConfig.from_legacy_kwargs(
                budget_bytes=1 << 24, sharding=sharding))
            sharded.register(st, columnar_for_stats=cols)

            q = lambda s: [
              s.table("t").filter(E.cmp("n1", ">", 300)).project("n1","n2"),
              s.table("t").filter(E.cmp("n2", ">", 1000)).project("n2"),
            ]
            r1 = plain.run_batch(q(plain), mqo=True)
            r2 = sharded.run_batch(q(sharded), mqo=True)
            for a, b in zip(r1.results, r2.results):
                assert a.table.row_multiset() == b.table.row_multiset()
            print("REL_SPMD_OK")
        """)
        assert "REL_SPMD_OK" in out


class TestElasticRestore:
    def test_save_on_4_restore_on_2(self, tmp_path):
        save_code = f"""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.ckpt.checkpoint import CheckpointManager
            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh((4,), ("data",))
            sh = NamedSharding(mesh, P("data"))
            tree = {{"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                                         sh)}}
            mgr = CheckpointManager(r"{tmp_path}")
            mgr.save(1, tree, blocking=True)
            print("SAVED")
        """
        assert "SAVED" in _run(save_code, devices=4)
        restore_code = f"""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.ckpt.checkpoint import CheckpointManager
            from repro.launch.mesh import make_test_mesh
            mesh = make_test_mesh((2,), ("data",))
            sh = {{"w": NamedSharding(mesh, P("data"))}}
            mgr = CheckpointManager(r"{tmp_path}")
            step, tree = mgr.restore({{"w": jnp.zeros((8, 8))}},
                                     shardings=sh)
            assert step == 1
            np.testing.assert_array_equal(
                np.asarray(tree["w"]), np.arange(64.0).reshape(8, 8))
            assert len(tree["w"].sharding.device_set) == 2
            print("ELASTIC_OK")
        """
        assert "ELASTIC_OK" in _run(restore_code, devices=2)


class TestGradCompression:
    def test_bf16_allreduce_in_lowered_program(self):
        """The compressed step emits a bf16 cross-data all-reduce (half
        the ICI bytes).  Asserted on the pre-optimization lowering: the
        CPU backend's algebraic simplifier hoists the convert above the
        reduce, while the TPU backend keeps bf16 reductions — so the
        post-optimization check is only meaningful on TPU."""
        out = _run("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            try:
                from jax import shard_map as _sm
            except ImportError:
                from jax.experimental.shard_map import shard_map as _sm
            from repro.launch.mesh import make_test_mesh

            def shard_map(f, **kw):
                # check_vma (new jax) vs check_rep (old jax)
                kw.pop("check_vma", None)
                try:
                    return _sm(f, **kw, check_vma=False)
                except TypeError:
                    return _sm(f, **kw, check_rep=False)

            mesh = make_test_mesh((4,), ("data",))
            W = jnp.zeros((256, 256))
            X = jnp.zeros((32, 256))

            def loss(w, x):
                return jnp.sum(jnp.tanh(x @ w) ** 2)

            def step_f32(w, x):
                g = jax.grad(loss)(w, x)
                return jax.lax.pmean(g, "data")

            def step_bf16(w, x):
                g = jax.grad(loss)(w, x)
                g16 = g.astype(jnp.bfloat16)
                return jax.lax.pmean(g16, "data").astype(jnp.float32)

            def lower(step):
                f = shard_map(step, mesh=mesh,
                              in_specs=(P(), P("data", None)),
                              out_specs=P(), check_vma=False)
                return jax.jit(f).lower(W, X).as_text()

            def ar_dtypes(txt):
                # StableHLO all_reduce result type follows on later
                # lines: inspect the 600 chars after each occurrence
                out = []
                for chunk in txt.split('stablehlo.all_reduce')[1:]:
                    window = chunk[:600]
                    if 'bf16>' in window:
                        out.append('bf16')
                    elif 'f32>' in window:
                        out.append('f32')
                return out

            assert "f32" in ar_dtypes(lower(step_f32))
            assert "bf16" in ar_dtypes(lower(step_bf16))
            # numerics: compressed result within bf16 quantization
            f = jax.jit(shard_map(step_f32, mesh=mesh,
                                  in_specs=(P(), P("data", None)),
                                  out_specs=P(), check_vma=False))
            c = jax.jit(shard_map(step_bf16, mesh=mesh,
                                  in_specs=(P(), P("data", None)),
                                  out_specs=P(), check_vma=False))
            rng = np.random.default_rng(0)
            w = jnp.asarray(rng.standard_normal((256, 256)) * 0.05,
                            jnp.float32)
            x = jnp.asarray(rng.standard_normal((32, 256)), jnp.float32)
            np.testing.assert_allclose(np.asarray(f(w, x)),
                                       np.asarray(c(w, x)),
                                       atol=1e-2, rtol=2e-2)
            print("GRAD_COMPRESS_OK")
        """, devices=4)
        assert "GRAD_COMPRESS_OK" in out
