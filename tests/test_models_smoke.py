"""Per-arch smoke tests: reduced configs, forward/train/decode on CPU."""
from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.models.decoder import init_cache
from repro.models.model import decode_step, forward, init_params, loss_fn

RNG = np.random.default_rng(0)
ALL = list_configs()


def _batch(cfg, B=2, T=24):
    n_tok = T - cfg.n_prefix_tokens
    batch = {
        "tokens": jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, n_tok)), jnp.int32),
        "labels": jnp.asarray(
            RNG.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
        "mask": jnp.ones((B, T), jnp.float32),
    }
    if cfg.n_prefix_tokens:
        batch["prefix_embeds"] = jnp.asarray(
            RNG.standard_normal((B, cfg.n_prefix_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL)
class TestArchSmoke:
    def test_forward_shapes_finite(self, arch):
        cfg = get_config(arch + "-smoke")
        params = init_params(cfg, 0)
        b = _batch(cfg)
        logits = forward(params, b["tokens"], cfg,
                         b.get("prefix_embeds"))
        assert logits.shape == (2, 24, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()

    def test_train_step_reduces_nothing_nan(self, arch):
        from repro.train.optimizer import OptConfig
        from repro.train.train_step import (init_train_state,
                                            make_train_step)

        cfg = get_config(arch + "-smoke")
        params = init_params(cfg, 0)
        opt = init_train_state(cfg, params)
        step = jax.jit(make_train_step(cfg, OptConfig(peak_lr=1e-3)))
        b = _batch(cfg)
        params, opt, metrics = step(params, opt, b)
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(params))

    def test_decode_step_runs(self, arch):
        cfg = replace(get_config(arch + "-smoke"), n_prefix_tokens=0)
        params = init_params(cfg, 0)
        cache = init_cache(cfg, 2, 32, jnp.float32)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, cache = decode_step(params, cache, tok, jnp.int32(0), cfg)
        assert logits.shape == (2, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ALL)
def test_decode_matches_forward(arch):
    """KV/state caches must reproduce teacher-forced logits exactly."""
    cfg = replace(get_config(arch + "-smoke"), n_prefix_tokens=0)
    if cfg.n_experts:   # dropless routing for the consistency check
        cfg = replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(cfg, 0)
    B, T = 2, 16
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, T)), jnp.int32)
    full = forward(params, toks, cfg)
    cache = init_cache(cfg, B, T, jnp.float32)
    worst = 0.0
    for t in range(T):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg)
        worst = max(worst, float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert worst < 1e-3, f"{arch}: decode diverged from forward ({worst})"


def test_param_counts_match_assignment():
    """Full configs must land near the published parameter counts."""
    expect = {
        "llama4-scout-17b-a16e": (108e9, 16e9),   # ~109B total / ~17B act
        "deepseek-v2-236b": (235e9, 21e9),
        "granite-8b": (8e9, 8e9),
        "phi4-mini-3.8b": (3.8e9, 3.8e9),
        "gemma3-12b": (12e9, 12e9),
        "gemma3-1b": (1.0e9, 1.0e9),
        "falcon-mamba-7b": (7e9, 7e9),
        "recurrentgemma-9b": (9e9, 9e9),
        "internvl2-2b": (1.8e9, 1.8e9),
        "musicgen-large": (3.3e9, 3.3e9),
    }
    for arch, (want_total, want_active) in expect.items():
        total, active = get_config(arch).param_count()
        assert 0.5 * want_total < total < 1.7 * want_total, \
            (arch, total / 1e9)
        assert 0.5 * want_active < active < 1.8 * want_active, \
            (arch, active / 1e9)


def test_moe_capacity_drops_tokens_gracefully():
    from repro.models.ffn import moe_forward
    from repro.models.model import model_specs
    from repro.models.common import materialize_params

    cfg = replace(get_config("llama4-scout-17b-a16e-smoke"),
                  capacity_factor=0.5)
    params = init_params(cfg, 0)
    b = _batch(cfg, B=2, T=16)
    logits = forward(params, b["tokens"], cfg)
    assert np.isfinite(np.asarray(logits)).all()
