"""MCKP solver: DP vs brute-force (hypothesis property tests)."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.candidates import KnapsackItem
from repro.core.covering import CoveringExpression
from repro.core.identify import SimilarSubexpression
from repro.core.mckp import solve_bruteforce, solve_mckp


def _item(group: int, value: float, weight: int) -> KnapsackItem:
    se = SimilarSubexpression(psi=b"x" * 16)
    ce = CoveringExpression(se=se, tree=None, psi=se.psi)  # type: ignore
    ce.value, ce.weight = value, weight
    return KnapsackItem(ces=(ce,), group=group)


items_strategy = st.lists(
    st.tuples(st.integers(0, 4),                      # group
              st.floats(0.1, 100, allow_nan=False),   # value
              st.integers(0, 50)),                    # weight
    min_size=0, max_size=12,
).map(lambda triples: [_item(g, v, w) for g, v, w in triples])


class TestDPvsBruteForce:
    @settings(max_examples=200, deadline=None)
    @given(items=items_strategy, capacity=st.integers(0, 120))
    def test_dp_matches_bruteforce_value(self, items, capacity):
        dp = solve_mckp(items, capacity, max_buckets=4096)
        bf = solve_bruteforce(items, capacity)
        assert dp.total_weight <= capacity
        assert dp.total_value == pytest.approx(bf.total_value, rel=1e-9)

    @settings(max_examples=100, deadline=None)
    @given(items=items_strategy, capacity=st.integers(0, 120))
    def test_at_most_one_per_group(self, items, capacity):
        dp = solve_mckp(items, capacity)
        groups = [it.group for it in dp.items]
        assert len(groups) == len(set(groups))


class TestBasics:
    def test_empty(self):
        sol = solve_mckp([], 100)
        assert sol.items == [] and sol.total_value == 0

    def test_budget_zero_selects_nothing_heavy(self):
        sol = solve_mckp([_item(0, 10, 5)], 0)
        assert sol.items == []

    def test_zero_weight_items_always_fit(self):
        sol = solve_mckp([_item(0, 10, 0), _item(1, 5, 0)], 1)
        assert sol.total_value == 15

    def test_prefers_higher_value_in_group(self):
        sol = solve_mckp([_item(0, 10, 5), _item(0, 20, 5)], 10)
        assert sol.total_value == 20
        assert len(sol.items) == 1

    def test_bucketing_never_exceeds_budget(self):
        # coarse buckets round weights UP -> conservative
        items = [_item(i, 1.0, 1000 + i) for i in range(20)]
        sol = solve_mckp(items, 10_000, max_buckets=8)
        assert sol.total_weight <= 10_000

    def test_large_instance_runs_fast(self):
        items = [_item(g, float((g * 7 + j) % 13 + 1), (j * 97 + g) % 4096)
                 for g in range(50) for j in range(8)]
        sol = solve_mckp(items, 1 << 20, max_buckets=2048)
        assert sol.total_value > 0
