import os
import sys

# src-layout import without installation
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.relational import (I32, STR, F32, Schema, Session,
                              SessionConfig, expr as E, make_storage)


@pytest.fixture(scope="session")
def hr_data():
    """The paper's running-example catalog (employees/departments/
    salaries) as typed numpy columns."""
    rng = np.random.default_rng(7)
    n_emp, n_dept, n_sal = 3000, 40, 6000
    g = np.zeros((n_emp, 4), np.uint8)
    g[:, 0] = np.where(rng.random(n_emp) < 0.5, ord("F"), ord("M"))
    emp = {
        "emp_id": np.arange(n_emp, dtype=np.int32),
        "name": rng.integers(97, 123, (n_emp, 12)).astype(np.uint8),
        "gender": g,
        "age": rng.integers(18, 65, n_emp).astype(np.int32),
        "dep": rng.integers(0, n_dept, n_emp).astype(np.int32),
    }
    loc = np.zeros((n_dept, 4), np.uint8)
    us = rng.random(n_dept) < 0.5
    loc[us, 0], loc[us, 1] = ord("u"), ord("s")
    loc[~us, 0], loc[~us, 1] = ord("f"), ord("r")
    dept = {
        "dept_id": np.arange(n_dept, dtype=np.int32),
        "dept_name": rng.integers(97, 123, (n_dept, 12)).astype(np.uint8),
        "location": loc,
    }
    sal = {
        "sal_emp_id": rng.integers(0, n_emp, n_sal).astype(np.int32),
        "salary": rng.integers(10_000, 90_000, n_sal).astype(np.int32),
        "from_year": rng.integers(2000, 2020, n_sal).astype(np.int32),
    }
    schemas = {
        "employees": Schema.of(("emp_id", I32), ("name", STR(12)),
                               ("gender", STR(4)), ("age", I32),
                               ("dep", I32)),
        "departments": Schema.of(("dept_id", I32), ("dept_name", STR(12)),
                                 ("location", STR(4))),
        "salaries": Schema.of(("sal_emp_id", I32), ("salary", I32),
                              ("from_year", I32)),
    }
    return {
        "employees": (schemas["employees"], n_emp, emp),
        "departments": (schemas["departments"], n_dept, dept),
        "salaries": (schemas["salaries"], n_sal, sal),
    }


def build_session(hr_data, fmt="columnar", budget=1 << 26) -> Session:
    sess = Session.from_config(
        SessionConfig.from_legacy_kwargs(budget_bytes=budget))
    for name, (schema, nrows, cols) in hr_data.items():
        st, _ = make_storage(name, schema, nrows, fmt, cols=cols)
        sess.register(st, columnar_for_stats=cols)
    return sess


@pytest.fixture()
def hr_session(hr_data):
    return build_session(hr_data)


def hr_queries(sess: Session):
    """The paper's three running-example queries (§3)."""
    emp, dept, sal = (sess.table("employees"), sess.table("departments"),
                      sess.table("salaries"))
    q1 = (emp.filter(E.cmp("gender", "==", "F"))
          .join(dept.filter(E.cmp("location", "==", "us")),
                "dep", "dept_id")
          .join(sal.filter(E.cmp("salary", ">", 20000)),
                "emp_id", "sal_emp_id")
          .project("name", "dept_name", "salary")
          .sort("salary", desc=True))
    q2 = (emp.filter(E.cmp("gender", "==", "F"))
          .join(dept.filter(E.cmp("location", "==", "us")),
                "dep", "dept_id")
          .join(sal.filter(E.cmp("from_year", ">=", 2010)),
                "emp_id", "sal_emp_id")
          .project("name", "dept_name", "from_year"))
    q3 = (emp.filter(E.cmp("age", ">", 30))
          .join(sal.filter(E.cmp("salary", ">", 30000)),
                "emp_id", "sal_emp_id")
          .project("emp_id", "name", "salary", "from_year"))
    return [q1, q2, q3]
