"""Dry-run integration: full-size configs lower+compile on the
production meshes (subprocess: the dryrun module owns XLA_FLAGS)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_cell(arch: str, shape: str, multi_pod: bool) -> dict:
    code = f"""
        from repro.launch.dryrun import run_cell
        import json
        r = run_cell("{arch}", "{shape}", multi_pod={multi_pod},
                     save=False)
        print("RESULT_JSON:" + json.dumps(
            {{k: v for k, v in r.items() if k != "traceback"}},
            default=str))
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT_JSON:")][0]
    return json.loads(line[len("RESULT_JSON:"):])


@pytest.mark.slow
class TestDryRun:
    def test_decode_cell_single_pod(self):
        r = _run_cell("granite-8b", "decode_32k", False)
        assert r["status"] == "ok", r.get("error")
        assert r["chips"] == 256
        assert r["flops_per_device"] > 0
        assert r["roofline"]["dominant"] in ("compute", "memory",
                                             "collective")

    def test_decode_cell_multi_pod(self):
        r = _run_cell("granite-8b", "decode_32k", True)
        assert r["status"] == "ok", r.get("error")
        assert r["chips"] == 512

    def test_long_context_ssm_cell(self):
        r = _run_cell("falcon-mamba-7b", "long_500k", False)
        assert r["status"] == "ok", r.get("error")

    def test_long_context_skip_for_full_attention(self):
        r = _run_cell("granite-8b", "long_500k", False)
        assert r["status"] == "skipped"
        assert "full-attention" in r["reason"]
