"""Online QueryService API (ISSUE 3): window-closing semantics
(count / deadline / flush), lazy handle resolution, explain() contents,
SessionConfig, bit-identity of submit-then-flush vs legacy run_batch,
single-query resident resume, memory-pressure-aware MCKP capacity, and
the deferred-sync fused Sort path.
"""
import numpy as np
import pytest

from conftest import build_session, hr_queries
from repro.relational import (ExecutionConfig, I32, MemoryConfig, MqoConfig,
                              QueryService, Schema, Session, SessionConfig,
                              expr as E, logical as L, make_storage,
                              next_pow2)

S = Schema.of(("a", I32), ("b", I32), ("c", I32))


def _mk_session(budget=1 << 24, nrows=2000, **kw) -> Session:
    rng = np.random.default_rng(9)
    cols = {c: rng.integers(0, 100, nrows).astype(np.int32)
            for c in ("a", "b", "c")}
    sess = Session.from_config(
        SessionConfig.from_legacy_kwargs(budget_bytes=budget, **kw))
    st, _ = make_storage("t", S, nrows, "columnar", cols=cols)
    sess.register(st)
    return sess


def _shared_query(sess):
    return sess.table("t").filter(E.cmp("a", ">", 50)).project("a", "b")


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _tables_bit_identical(ta, tb):
    assert ta.nrows == tb.nrows
    assert ta.schema.names == tb.schema.names
    for n in ta.schema.names:
        assert np.array_equal(np.asarray(ta.columns[n])[: ta.nrows],
                              np.asarray(tb.columns[n])[: tb.nrows]), n


# ---------------------------------------------------------------------------
# window lifecycle
# ---------------------------------------------------------------------------
class TestWindowClosing:
    def test_count_trigger_closes_inside_submit(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=2)
        h1 = svc.submit(_shared_query(sess))
        assert not h1.done and svc.pending == 1
        h2 = svc.submit(_shared_query(sess))
        # the second arrival filled the window: both resolved already
        assert h1.done and h2.done and svc.pending == 0

    def test_deadline_trigger_via_poll(self):
        sess = _mk_session()
        clock = FakeClock()
        svc = QueryService(sess, max_batch=100, max_wait_s=5.0,
                           clock=clock)
        h = svc.submit(_shared_query(sess))
        assert not svc.poll() and not h.done      # deadline not reached
        clock.advance(5.1)
        assert svc.poll() and h.done
        assert not svc.poll()                     # nothing pending now

    def test_overdue_window_flushes_before_new_arrival(self):
        sess = _mk_session()
        clock = FakeClock()
        svc = QueryService(sess, max_batch=100, max_wait_s=5.0,
                           clock=clock)
        h1 = svc.submit(_shared_query(sess))
        clock.advance(10.0)
        h2 = svc.submit(_shared_query(sess))
        # h1's window was due: it ran BEFORE h2 was accepted, and h2
        # opened a fresh window
        assert h1.done and not h2.done
        assert svc.pending == 1

    def test_explicit_flush(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=100)
        handles = [svc.submit(_shared_query(sess)) for _ in range(3)]
        assert not any(h.done for h in handles)
        batch = svc.flush()
        assert all(h.done for h in handles)
        assert len(batch.results) == 3
        assert svc.flush() is None                # empty flush is a no-op

    def test_result_forces_pending_window(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=100)
        h = svc.submit(_shared_query(sess))
        table = h.result()                        # must not deadlock
        assert h.done and table.nrows > 0

    def test_flush_expired_closes_due_window(self):
        """ISSUE 4 satellite: a deadline-expired window closes through
        ``flush_expired()`` alone — no submit/result call required (the
        ROADMAP's cooperative window-closing open item)."""
        sess = _mk_session()
        clock = FakeClock()
        svc = QueryService(sess, max_batch=100, max_wait_s=5.0,
                           clock=clock)
        h = svc.submit(_shared_query(sess))
        assert svc.flush_expired() is None        # not due yet
        assert not h.done and svc.pending == 1
        clock.advance(5.1)
        batch = svc.flush_expired()               # due: closes, returns
        assert batch is not None and len(batch.results) == 1
        assert h.done and svc.pending == 0
        assert svc.flush_expired() is None        # nothing pending

    def test_flush_expired_never_cuts_filling_window_short(self):
        sess = _mk_session()
        clock = FakeClock()
        svc = QueryService(sess, max_batch=100, max_wait_s=5.0,
                           clock=clock)
        handles = [svc.submit(_shared_query(sess)) for _ in range(3)]
        clock.advance(4.9)
        assert svc.flush_expired() is None        # within the deadline
        assert svc.pending == 3
        clock.advance(0.2)
        batch = svc.flush_expired()
        assert len(batch.results) == 3
        assert all(h.done for h in handles)

    def test_flush_expired_without_deadline_is_noop(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=100)   # no max_wait_s
        h = svc.submit(_shared_query(sess))
        assert svc.flush_expired() is None        # no deadline configured
        assert not h.done and svc.pending == 1
        svc.flush()
        assert h.done

    def test_handles_resolve_in_submission_order(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=100)
        t = sess.table("t")
        thresholds = [20, 40, 60, 80]
        handles = [svc.submit(t.filter(E.cmp("a", ">", thr)).project("a"))
                   for thr in thresholds]
        svc.flush()
        counts = [h.result().nrows for h in handles]
        # descending thresholds -> ascending row counts: each handle got
        # ITS OWN query's result (order preserved through the window)
        assert counts == sorted(counts, reverse=True)
        assert [h.explain()["position"] for h in handles] == [0, 1, 2, 3]

    def test_explain_before_resolution_raises(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=100)
        h = svc.submit(_shared_query(sess))
        with pytest.raises(RuntimeError):
            h.explain()


# ---------------------------------------------------------------------------
# explain() contents + cross-window reuse
# ---------------------------------------------------------------------------
class TestExplain:
    def test_cold_window_reports_ce_without_reuse(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=2)
        h1 = svc.submit(_shared_query(sess))
        svc.submit(_shared_query(sess))
        e = h1.explain()
        assert e["status"] == "done" and e["mqo"] and e["window"] == 0
        assert "filter" in e["submitted"] or "scan" in e["submitted"]
        assert isinstance(e["plan"], str) and e["plan"]
        assert len(e["ces"]) == 1                 # identical pair -> one CE
        ce = e["ces"][0]
        assert ce["m"] == 2
        assert not ce["cache_hit"] and not ce["resident_repriced"]
        assert not e["resident_reuse"]

    def test_warm_window_reports_resident_hit(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=2)
        svc.submit(_shared_query(sess))
        svc.submit(_shared_query(sess))           # window 0: materializes
        h = svc.submit(_shared_query(sess))
        svc.submit(_shared_query(sess))           # window 1: reuses
        e = h.explain()
        assert e["window"] == 1
        assert e["resident_reuse"]
        ce = e["ces"][0]
        assert ce["cache_hit"] and ce["resident_repriced"]
        assert ce["weight"] == 0                  # already-paid MCKP item

    def test_single_query_resident_resume(self):
        """ROADMAP open item: a window with ONE query (below the k
        consumer threshold) still rewrites against a still-resident CE
        whose strict fingerprint matches."""
        sess = _mk_session()
        svc = QueryService(sess, max_batch=2)
        svc.submit(_shared_query(sess))
        svc.submit(_shared_query(sess))           # materialize the CE
        h = svc.submit(_shared_query(sess))
        batch = svc.flush()                       # window of ONE query
        e = h.explain()
        assert e["window_size"] == 1
        assert e["resident_reuse"]
        assert e["ces"][0]["single_resume"]
        assert batch.mqo.report.n_single_resume >= 1
        assert batch.mqo.report.n_resident >= 1
        # and the resumed result matches independent execution
        base = sess.run_batch([_shared_query(sess)], mqo=False)
        assert (base.results[0].table.row_multiset()
                == h.result().row_multiset())

    def test_single_query_no_resume_without_matching_resident(self):
        sess = _mk_session()
        svc = QueryService(sess, max_batch=2)
        svc.submit(_shared_query(sess))
        svc.submit(_shared_query(sess))
        # same structure, different predicate: strict fp differs
        other = sess.table("t").filter(E.cmp("a", "<", 10)).project("a", "b")
        h = svc.submit(other)
        batch = svc.flush()
        assert batch.mqo.report.n_single_resume == 0
        assert not h.explain()["resident_reuse"]

    def test_same_structure_windows_stay_resident_side_by_side(self):
        """Strict-keyed CE cache: windows over the same template family
        (same loose psi, different merged predicates) must not evict
        one another — every recurring window gets warm reuse."""
        sess = _mk_session(nrows=4000)
        t = sess.table("t")
        fam = lambda thr: t.filter(E.cmp("a", ">", thr)).project("a", "b")
        svc = QueryService(sess, max_batch=2)
        for thr in (50, 70):                      # two same-psi windows
            svc.submit(fam(thr))
            svc.submit(fam(thr))
        # repeat the SAME two windows: both must hit their residents
        for thr in (50, 70):
            h = svc.submit(fam(thr))
            svc.submit(fam(thr))
            e = h.explain()
            assert e["resident_reuse"], f"threshold {thr} lost residency"


# ---------------------------------------------------------------------------
# one-shot path == pre-closed window
# ---------------------------------------------------------------------------
class TestBitIdentity:
    @pytest.mark.parametrize("mqo", [False, True])
    def test_submit_flush_matches_run_batch(self, hr_data, mqo):
        sess_a = build_session(hr_data)
        sess_b = build_session(hr_data)
        batch = sess_a.run_batch(hr_queries(sess_a), mqo=mqo)
        svc = QueryService(sess_b, max_batch=100, mqo=mqo)
        handles = [svc.submit(q) for q in hr_queries(sess_b)]
        svc.flush()
        for qr, h in zip(batch.results, handles):
            _tables_bit_identical(qr.table, h.result())

    def test_run_batch_is_a_window(self, hr_data):
        """run_batch routes through the service machinery: the session's
        one-shot service exists after the first call and its window
        counter advances per batch."""
        sess = build_session(hr_data)
        assert sess._oneshot is None
        sess.run_batch(hr_queries(sess))
        assert isinstance(sess._oneshot, QueryService)
        n = sess._oneshot._n_windows
        sess.run_batch(hr_queries(sess))
        assert sess._oneshot._n_windows == n + 1


# ---------------------------------------------------------------------------
# SessionConfig
# ---------------------------------------------------------------------------
class TestSessionConfig:
    def test_from_config_equals_legacy_kwargs(self):
        cfg = SessionConfig(
            execution=ExecutionConfig(fuse=False, defer_sync=False,
                                      use_scan_cache=False),
            memory=MemoryConfig(budget_bytes=1 << 20, policy="benefit",
                                retain_across_batches=False),
            mqo=MqoConfig(k=3))
        sess = Session.from_config(cfg)
        legacy = Session(budget_bytes=1 << 20, fuse=False,
                         defer_sync=False, use_scan_cache=False,
                         policy="benefit", retain_across_batches=False)
        for attr in ("budget", "fuse", "defer_sync", "use_scan_cache",
                     "retain_across_batches"):
            assert getattr(sess, attr) == getattr(legacy, attr), attr
        assert sess.memory.policy == legacy.memory.policy == "benefit"
        assert sess.config.mqo.k == 3

    def test_config_is_frozen(self):
        cfg = SessionConfig()
        with pytest.raises(Exception):
            cfg.memory = MemoryConfig()
        with pytest.raises(Exception):
            cfg.memory.budget_bytes = 1

    def test_with_helpers_build_variants(self):
        cfg = SessionConfig().with_memory(budget_bytes=123) \
                             .with_execution(fuse=False) \
                             .with_mqo(k=5)
        assert cfg.memory.budget_bytes == 123
        assert not cfg.execution.fuse
        assert cfg.mqo.k == 5
        # defaults untouched
        assert SessionConfig().memory.budget_bytes == 1 << 30

    def test_legacy_shim_defaults_match_config_defaults(self):
        assert Session().config == SessionConfig().with_memory(
            budget_bytes=1 << 30)

    def test_config_and_legacy_kwargs_clash_raises(self):
        with pytest.raises(ValueError, match="not both"):
            Session(budget_bytes=1 << 20, config=SessionConfig())

    def test_service_inherits_mqo_config(self):
        sess = Session.from_config(SessionConfig(mqo=MqoConfig(k=4)))
        svc = sess.service(max_batch=3)
        assert svc.k == 4 and svc.max_batch == 3


# ---------------------------------------------------------------------------
# memory-pressure-aware MCKP capacity
# ---------------------------------------------------------------------------
class TestPlanningCapacity:
    def test_hot_scan_pool_shrinks_capacity(self):
        sess = _mk_session(budget=64 * 1024, nrows=4000)
        assert sess.planning_capacity() == sess.budget   # nothing hot
        # heat the scan pool (3 columns x 4096 cap x 4B = 48 KiB)
        sess.run_batch([sess.table("t").filter(E.cmp("a", ">", -1))],
                       mqo=False)
        scan_used = sess.memory.pools["scan"].stats.used
        assert scan_used > 0
        cap = sess.planning_capacity()
        assert cap == sess.budget - scan_used
        # the window-level optimizer actually planned at that capacity
        res = sess.run_batch([_shared_query(sess), _shared_query(sess)])
        assert res.mqo.report.budget <= sess.budget - scan_used

    def test_retained_residents_shrink_capacity(self):
        sess = _mk_session(budget=1 << 24)
        res = sess.run_batch([_shared_query(sess), _shared_query(sess)])
        assert res.mqo.report.n_selected >= 1
        ce_used = sess.memory.pools["ce"].stats.used
        scan_used = sess.memory.pools["scan"].stats.used
        assert ce_used > 0
        assert sess.planning_capacity() == sess.budget - scan_used - ce_used

    def test_explicit_budget_still_caps(self):
        sess = _mk_session(budget=1 << 24)
        assert sess.planning_capacity(4096) <= 4096
        assert sess.planning_capacity(0) == 0    # no-caching baseline

    def test_pressure_aware_off_restores_full_budget(self):
        cfg = SessionConfig(memory=MemoryConfig(budget_bytes=64 * 1024),
                            mqo=MqoConfig(pressure_aware=False))
        sess = Session.from_config(cfg)
        rng = np.random.default_rng(9)
        cols = {c: rng.integers(0, 100, 4000).astype(np.int32)
                for c in ("a", "b", "c")}
        st, _ = make_storage("t", S, 4000, "columnar", cols=cols)
        sess.register(st)
        sess.run_batch([sess.table("t").filter(E.cmp("a", ">", -1))],
                       mqo=False)
        assert sess.planning_capacity() == sess.budget

    def test_capacity_never_negative(self):
        sess = _mk_session(budget=1024, nrows=4000)   # pool >> budget
        sess.run_batch([sess.table("t").filter(E.cmp("a", ">", -1))],
                       mqo=False)
        assert sess.planning_capacity() >= 0

    def test_retention_off_plans_at_full_budget(self):
        """With retention off the CE cache is cleared at window start,
        so a repeat batch must plan at the full capacity again — the
        previous batch's (about-to-be-freed) CE bytes must not shrink
        the MCKP capacity."""
        sess = _mk_session(budget=1 << 24, retain_across_batches=False)
        first = sess.run_batch([_shared_query(sess), _shared_query(sess)])
        assert first.mqo.report.n_selected >= 1
        repeat = sess.run_batch([_shared_query(sess), _shared_query(sess)])
        scan_used = sess.memory.pools["scan"].stats.used
        assert repeat.mqo.report.budget == sess.budget - scan_used
        assert repeat.mqo.report.n_selected >= 1   # worksharing intact


class TestMqoConfigHonored:
    def test_run_batch_uses_config_mqo_enabled(self):
        cfg = SessionConfig(memory=MemoryConfig(budget_bytes=1 << 24),
                            mqo=MqoConfig(enabled=False))
        sess = Session.from_config(cfg)
        rng = np.random.default_rng(9)
        cols = {c: rng.integers(0, 100, 2000).astype(np.int32)
                for c in ("a", "b", "c")}
        st, _ = make_storage("t", S, 2000, "columnar", cols=cols)
        sess.register(st)
        res = sess.run_batch([_shared_query(sess), _shared_query(sess)])
        assert res.mqo is None                 # config disabled the MQO
        res = sess.run_batch([_shared_query(sess), _shared_query(sess)],
                             mqo=True)         # explicit override wins
        assert res.mqo is not None


# ---------------------------------------------------------------------------
# deferred-sync fused Sort
# ---------------------------------------------------------------------------
class TestSortDeferredSync:
    @pytest.mark.parametrize("desc", [False, True])
    @pytest.mark.parametrize("by", ["a", "x"])
    def test_fused_sort_bit_identical_to_eager(self, desc, by):
        schema = Schema.of(("a", I32), ("x", I32), ("b", I32))
        rng = np.random.default_rng(3)
        cols = {"a": rng.integers(0, 50, 3000).astype(np.int32),
                "x": rng.integers(-100, 100, 3000).astype(np.int32),
                "b": np.arange(3000, dtype=np.int32)}

        def mk(fused):
            s = Session.from_config(SessionConfig.from_legacy_kwargs(
                budget_bytes=1 << 24, fuse=fused,
                defer_sync=fused, use_scan_cache=fused))
            st, _ = make_storage("s", schema, 3000, "columnar", cols=cols)
            s.register(st)
            return s

        q = lambda s: (s.table("s").filter(E.cmp("a", ">", 25))
                       .sort(by, desc=desc))
        te = mk(False).run_one(q(mk(False))).table
        tf = mk(True).run_one(q(mk(True))).table
        # stable sort over identical masked keys: live rows must match
        # bit for bit, including tie order
        _tables_bit_identical(te, tf)

    def test_sort_output_capacity_sized_from_estimate(self):
        sess = _mk_session(nrows=4000)
        q = sess.table("t").filter(E.cmp("a", ">", 90)).sort("b")
        table = sess.run_one(q).table
        est = sess.cost_model.sort_estimate(table.nrows)
        # capacity tracks the (exact) estimate, not the scan capacity
        assert table.capacity <= next_pow2(max(int(est * 1.25), 1))
        assert table.capacity < 4096
