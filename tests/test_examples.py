"""Examples must run end-to-end (subprocess smoke, reduced sizes)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script, *args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


@pytest.mark.slow
class TestExamples:
    def test_quickstart(self):
        out = _run("quickstart.py")
        assert "identical=True" in out
        assert "selected" in out
        assert "QueryService" in out          # online front-end snippet

    def test_analytics_server(self):
        out = _run("analytics_server.py", "--window", "6",
                   "--scale-rows", "20000")
        assert "aggregate ratio" in out
        assert "warm speedup over cold" in out

    def test_llm_serving_mqo(self):
        out = _run("llm_serving_mqo.py", "--requests", "6")
        assert "generations identical: True" in out

    def test_train_lm(self):
        out = _run("train_lm.py", "--steps", "40", "--width", "128",
                   "--layers", "2", "--seq-len", "128", "--batch", "4",
                   "--ckpt-dir", "/tmp/test_train_lm_ex")
        assert "improved" in out
