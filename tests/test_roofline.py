"""Roofline machinery: HLO collective parsing + term math."""
import pytest

from repro.launch.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                   collective_bytes_from_hlo,
                                   roofline_terms)

HLO = """
HloModule jit_step
  %x = f32[1024,512]{1,0} parameter(0)
  %all-reduce.5 = f32[1024,512]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64,128]{1,0} all-gather(%y), dimensions={0}
  %rs.2 = f32[32,32]{1,0} reduce-scatter(%z), dimensions={0}
  %a2a = bf16[16,16]{1,0} all-to-all(%w), dimensions={1}
  %cp = u8[100]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %dot.3 = f32[10,10]{1,0} dot(%a, %b)   // not a collective
  %note = f32[9] add(%c, %d), metadata={op_name="all-reduce-lookalike"}
"""


class TestCollectiveParser:
    def test_bytes_by_op(self):
        got = collective_bytes_from_hlo(HLO)
        assert got["by_op"]["all-reduce"] == 1024 * 512 * 4
        assert got["by_op"]["all-gather"] == 64 * 128 * 2
        assert got["by_op"]["reduce-scatter"] == 32 * 32 * 4
        assert got["by_op"]["all-to-all"] == 16 * 16 * 2
        assert got["by_op"]["collective-permute"] == 100
        assert got["total"] == sum(got["by_op"].values())

    def test_non_collectives_ignored(self):
        got = collective_bytes_from_hlo(HLO)
        assert got["op_counts"] == {"all-reduce": 1, "all-gather": 1,
                                    "reduce-scatter": 1, "all-to-all": 1,
                                    "collective-permute": 1}

    def test_async_start_variant(self):
        hlo = "%ar = f32[8]{0} all-reduce-start(%x), replica_groups={}"
        got = collective_bytes_from_hlo(hlo)
        assert got["by_op"]["all-reduce"] == 32

    def test_empty(self):
        assert collective_bytes_from_hlo("")["total"] == 0


class TestRooflineTerms:
    def _cell(self, flops, bytes_, coll, chips=256, active=1e9,
              tokens=1e6, kind="train"):
        return {"flops_per_device": flops,
                "bytes_accessed_per_device": bytes_,
                "collective_bytes_per_device": coll, "chips": chips,
                "params_active": active, "tokens_per_step": tokens,
                "step_kind": kind}

    def test_term_math(self):
        r = roofline_terms(self._cell(PEAK_FLOPS, HBM_BW, ICI_BW))
        assert r["compute_s"] == pytest.approx(1.0)
        assert r["memory_s"] == pytest.approx(1.0)
        assert r["collective_s"] == pytest.approx(1.0)

    def test_dominant_selection(self):
        r = roofline_terms(self._cell(PEAK_FLOPS, HBM_BW * 3, ICI_BW))
        assert r["dominant"] == "memory"
        r = roofline_terms(self._cell(PEAK_FLOPS * 5, HBM_BW, ICI_BW))
        assert r["dominant"] == "compute"
        r = roofline_terms(self._cell(PEAK_FLOPS, HBM_BW, ICI_BW * 9))
        assert r["dominant"] == "collective"

    def test_useful_ratio(self):
        # MODEL_FLOPS = 6*N*D for train; per-device = /chips
        cell = self._cell(flops=6e9 * 1e6 * 1 / 256, bytes_=1, coll=1,
                          active=1e9, tokens=1e6)
        r = roofline_terms(cell)
        assert r["useful_flops_ratio"] == pytest.approx(1.0)

    def test_inference_multiplier(self):
        train = roofline_terms(self._cell(1e12, 1, 1, kind="train"))
        serve = roofline_terms(self._cell(1e12, 1, 1, kind="decode"))
        assert train["model_flops_total"] == 3 * serve["model_flops_total"]

    def test_mfu_at_roofline_is_one(self):
        # perfectly useful compute-bound cell => MFU == 1
        flops = 6e9 * 1e6 / 256
        cell = self._cell(flops=flops, bytes_=0, coll=0)
        r = roofline_terms(cell)
        assert r["roofline_fraction_mfu"] == pytest.approx(1.0)
