"""Serving-layer prefix MQO: exactness, admission, budgets, arch weights."""
from dataclasses import replace

import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import init_params
from repro.serving.costs import ServingCostModel
from repro.serving.engine import ServingEngine
from repro.serving.request import (GenerationRequest, build_chain,
                                   identify_shared_prefixes, plan_requests)

RNG = np.random.default_rng(11)


def _cfg(name="granite-8b-smoke"):
    return replace(get_config(name), n_prefix_tokens=0)


def _requests(cfg, n_shared=3, shared_len=96, tail=12, seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, shared_len)
    reqs = []
    for i in range(n_shared):
        p = np.concatenate([shared,
                            rng.integers(0, cfg.vocab_size, tail + i)])
        reqs.append(GenerationRequest(i, p.astype(np.int32), 4))
    reqs.append(GenerationRequest(99, rng.integers(
        0, cfg.vocab_size, 40).astype(np.int32), 4))
    return reqs


class TestPrefixIdentification:
    def test_chain_blocks_and_tail(self):
        chain, tail = build_chain(np.arange(150, dtype=np.int32), 64)
        assert chain.n_tokens == 128 and len(tail) == 22
        assert chain.depth == 1

    def test_shared_prefix_found_at_every_depth(self):
        cfg = _cfg()
        reqs = plan_requests(_requests(cfg, shared_len=128), 32)
        ses = identify_shared_prefixes(reqs, k=2)
        lens = sorted(se.occurrences[0].node.n_tokens for se in ses)
        assert lens == [32, 64, 96, 128]

    def test_distinct_prompts_share_nothing(self):
        cfg = _cfg()
        rng = np.random.default_rng(1)
        reqs = plan_requests([
            GenerationRequest(i, rng.integers(
                0, cfg.vocab_size, 80).astype(np.int32), 2)
            for i in range(4)], 32)
        assert identify_shared_prefixes(reqs, k=2) == []


class TestEngineExactness:
    @pytest.mark.parametrize("budget", [1 << 14, 1 << 22])
    def test_generations_identical_with_mqo(self, budget):
        cfg = _cfg()
        params = init_params(cfg, 0)
        eng = ServingEngine(cfg, params, pool_budget_bytes=budget,
                            block_size=32, max_len=192)

        def mk():
            return [GenerationRequest(r.request_id, r.prompt.copy(),
                                      r.max_new_tokens)
                    for r in _requests(cfg)]

        base, _ = eng.run_batch(mk(), mqo=False)
        opt, rep = eng.run_batch(mk(), mqo=True)
        assert all((a == b).all() for a, b in zip(base, opt))
        assert rep.pool_used <= budget

    def test_prefill_savings_on_shared_workload(self):
        cfg = _cfg()
        params = init_params(cfg, 0)
        eng = ServingEngine(cfg, params, pool_budget_bytes=1 << 22,
                            block_size=32, max_len=192)
        _, rep = eng.run_batch(_requests(cfg, n_shared=4), mqo=True)
        assert rep.tokens_prefilled < rep.tokens_prefilled_baseline
        assert rep.n_selected >= 1

    def test_ssm_arch_prefix_caching(self):
        cfg = _cfg("falcon-mamba-7b-smoke")
        params = init_params(cfg, 0)
        eng = ServingEngine(cfg, params, pool_budget_bytes=1 << 20,
                            block_size=32, max_len=192)

        def mk():
            return [GenerationRequest(r.request_id, r.prompt.copy(),
                                      r.max_new_tokens)
                    for r in _requests(cfg)]

        base, _ = eng.run_batch(mk(), mqo=False)
        opt, rep = eng.run_batch(mk(), mqo=True)
        assert all((a == b).all() for a, b in zip(base, opt))
        # SSM state is O(1): the whole shared prefix costs the same
        # bytes as a single block
        cm = ServingCostModel(cfg)
        assert cm.state_bytes(1000) == cm.state_bytes(10)


class TestCrossBatchStateReuse:
    def test_warm_batch_skips_prefill_of_retained_prefixes(self):
        """ISSUE 2: prefix states admitted through the MemoryManager
        are retained across run_batch calls — a repeat batch prefills
        only what the pool does not already hold."""
        cfg = _cfg()
        params = init_params(cfg, 0)
        eng = ServingEngine(cfg, params, pool_budget_bytes=1 << 22,
                            block_size=32, max_len=192)

        def mk():
            return [GenerationRequest(r.request_id, r.prompt.copy(),
                                      r.max_new_tokens)
                    for r in _requests(cfg)]

        base, _ = eng.run_batch(mk(), mqo=False)
        cold, rep_cold = eng.run_batch(mk(), mqo=True)
        warm, rep_warm = eng.run_batch(mk(), mqo=True)
        assert rep_cold.n_selected >= 1
        assert rep_warm.tokens_prefilled < rep_cold.tokens_prefilled
        # exactness survives the warm path
        assert all((a == b).all() for a, b in zip(base, cold))
        assert all((a == b).all() for a, b in zip(base, warm))

    def test_retain_states_off_restores_cold_batches(self):
        cfg = _cfg()
        params = init_params(cfg, 0)
        eng = ServingEngine(cfg, params, pool_budget_bytes=1 << 22,
                            block_size=32, max_len=192,
                            retain_states=False)
        _, rep1 = eng.run_batch(_requests(cfg), mqo=True)
        _, rep2 = eng.run_batch(_requests(cfg), mqo=True)
        assert rep2.tokens_prefilled == rep1.tokens_prefilled


class TestArchWeights:
    def test_mla_lighter_than_gqa(self):
        gqa = ServingCostModel(get_config("granite-8b"))
        mla = ServingCostModel(get_config("deepseek-v2-236b"))
        n = 4096
        per_layer_gqa = gqa.state_bytes(n) / 36
        per_layer_mla = mla.state_bytes(n) / 60
        # granite GQA (kv=8, hd=128): 4096 B/token/layer; deepseek MLA
        # latent: 1152 B/token/layer (~3.6x; vs its own 128-head GQA
        # equivalent it is ~57x)
        assert per_layer_mla < per_layer_gqa / 3

    def test_local_window_clips_weight(self):
        cm = ServingCostModel(get_config("gemma3-12b"))
        # 5/6 of layers are window-clipped: doubling a long prefix must
        # grow bytes sub-linearly
        b1, b2 = cm.state_bytes(8192), cm.state_bytes(16384)
        # 40 of 48 layers are window-clipped constants => clearly
        # sub-linear growth (a pure-GQA arch would give exactly 2.0)
        assert b2 < 1.7 * b1

    def test_value_increases_with_consumers(self):
        """Paper Eq. 3: v(Ω) increases in m."""
        from repro.core.costmodel import price_ce
        from repro.core.covering import build_covering_expressions

        cfg = _cfg()
        reqs6 = plan_requests(_requests(cfg, n_shared=6), 32)
        ses = identify_shared_prefixes(reqs6, k=2)
        ces = build_covering_expressions(ses)
        cm = ServingCostModel(cfg)
        for ce in ces:
            price_ce(ce, cm)
        by_m = {}
        for ce in ces:
            by_m.setdefault(ce.se.occurrences[0].node.n_tokens, ce)
        # same prefix with more consumers has higher value
        ce = ces[0]
        v_before = ce.value
        ce.se.occurrences = ce.se.occurrences * 2
        price_ce(ce, cm)
        assert ce.value > v_before
