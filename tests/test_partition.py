"""Partitioned execution (ISSUE 4): partition-aware tables, pruning,
partition-grained MCKP admission / caching, and multi-device sharded
scans.

Covers:
  * partition layout + statistics (range/hash re-clustering);
  * pruning soundness — unit cases plus hypothesis property tests that
    pruned execution is bit-identical to unpruned on live rows, across
    both schemes and both storage formats;
  * partition-grained MCKP: a budget that cannot hold a full CE admits
    a strict subset of its partitions, partial hits compose resident +
    recomputed partitions, warm windows re-price resident partitions as
    zero-weight items;
  * the re-registration invalidation fix (per-partition statistics and
    partition-grained cache entries);
  * multi-device sharded scans (subprocess with 8 host devices).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.relational import (ExecutionConfig, MemoryConfig, Partitioning,
                              QueryService, Session, SessionConfig,
                              expr as E, make_storage)
from repro.relational.datagen import generate_columns, synthetic_schema
from repro.relational.partition import (assign_partitions, hash_bucket,
                                        linear_scan_chain,
                                        partition_table, prune_parts,
                                        restrict_to_parts)

SCHEMA = synthetic_schema(n_int=3, n_dbl=2, n_str=1)
NROWS = 8000
COLS = generate_columns(SCHEMA, NROWS, seed=11)


def make_session(fmt="columnar", partitioning=None, prune=True,
                 budget=1 << 26, nrows=NROWS, cols=None, name="t",
                 disk_latency=0.0):
    cols = COLS if cols is None else cols
    sess = Session.from_config(SessionConfig(
        execution=ExecutionConfig(prune=prune),
        memory=MemoryConfig(budget_bytes=budget)))
    sess.disk_latency_per_byte = disk_latency
    st, _ = make_storage(name, SCHEMA, nrows, fmt, cols=cols)
    sess.register(st, columnar_for_stats=cols, partitioning=partitioning)
    return sess


# ---------------------------------------------------------------------------
# layout + statistics
# ---------------------------------------------------------------------------
class TestPartitionLayout:
    def test_range_reclusters_contiguously(self):
        spec = Partitioning("n1", "range", 8)
        perm, reordered, info = partition_table(spec, NROWS, COLS)
        assert info.n_partitions == 8
        assert int(info.offsets[-1]) == NROWS
        # partitions tile the rows; n1 ranges are non-overlapping
        highs = []
        for pid in range(8):
            lo, hi = info.part_range(pid)
            if hi > lo:
                part = reordered["n1"][lo:hi]
                cs = info.col_stats[pid]["n1"]
                assert cs.vmin == part.min() and cs.vmax == part.max()
                highs.append((cs.vmin, cs.vmax))
        for (lo1, hi1), (lo2, hi2) in zip(highs, highs[1:]):
            assert hi1 <= lo2 + 1e-9

    def test_range_quantiles_balance(self):
        spec = Partitioning("n1", "range", 8)
        _, _, info = partition_table(spec, NROWS, COLS)
        sizes = [info.part_rows(p) for p in range(8)]
        assert min(sizes) > NROWS // 32     # quantile split: roughly even

    def test_hash_assignment_deterministic(self):
        spec = Partitioning("n1", "hash", 8)
        a = assign_partitions(COLS["n1"], spec)
        b = assign_partitions(COLS["n1"], spec)
        assert np.array_equal(a, b)
        assert set(np.unique(a)) <= set(range(8))

    def test_partitioned_multiset_equals_unpartitioned(self):
        base = make_session()
        part = make_session(partitioning=Partitioning("n1", "range", 8))
        q = lambda s: s.table("t").filter(
            E.cmp("n1", "<", 300)).project("n1", "n2")
        a = base.run_batch([q(base)], mqo=False).results[0].table
        b = part.run_batch([q(part)], mqo=False).results[0].table
        assert a.row_multiset() == b.row_multiset()


# ---------------------------------------------------------------------------
# pruning (unit)
# ---------------------------------------------------------------------------
class TestPruning:
    def _info(self, scheme="range", n=8):
        spec = Partitioning("n1", scheme, n)
        _, _, info = partition_table(spec, NROWS, COLS)
        return info

    def test_range_lt_prunes_high_partitions(self):
        info = self._info()
        live = prune_parts(E.cmp("n1", "<", 100), info)
        assert 0 < len(live) < info.n_partitions
        # every row with n1 < 100 lives in a surviving partition
        for pid in set(range(info.n_partitions)) - set(live):
            assert info.col_stats[pid]["n1"].vmin >= 100

    def test_hash_eq_prunes_to_one_bucket(self):
        info = self._info("hash")
        v = int(COLS["n1"][0])
        live = prune_parts(E.cmp("n1", "==", v), info)
        want = int(hash_bucket(np.asarray([v], np.int64), 8)[0])
        assert live == (want,) or live == ()

    def test_or_unions_survivors(self):
        info = self._info()
        lo = prune_parts(E.cmp("n1", "<", 100), info)
        hi = prune_parts(E.cmp("n1", ">", 900), info)
        both = prune_parts(E.or_(E.cmp("n1", "<", 100),
                                 E.cmp("n1", ">", 900)), info)
        assert set(both) == set(lo) | set(hi)

    def test_not_is_conservative(self):
        info = self._info()
        live = prune_parts(E.not_(E.cmp("n1", "<", 100)), info)
        # partitions entirely below 100 are refuted; the rest survive
        for pid in set(range(info.n_partitions)) - set(live):
            assert info.col_stats[pid]["n1"].vmax < 100

    def test_nan_partition_is_unprunable(self):
        """NaN poisons min/max interval reasoning (every compare is
        False), which would UNSOUNDLY prune a partition still holding
        qualifying non-NaN rows — such partitions must survive."""
        nrows = 64
        cols = {
            "n1": np.arange(nrows, dtype=np.int32),
            "d1": np.linspace(0.0, 1.0, nrows).astype(np.float32),
        }
        cols["d1"][3] = np.nan               # lands in partition 0
        spec = Partitioning("n1", "range", 4)
        _, reordered, info = partition_table(spec, nrows, cols)
        assert info.col_stats[0]["d1"].has_nan
        # partition 0 holds qualifying rows (small d1) AND a NaN
        live = prune_parts(E.cmp("d1", "<", 0.1), info)
        assert 0 in live
        # NaN satisfies != — the partition must survive that too
        live_ne = prune_parts(E.cmp("d1", "!=", 0.5), info)
        assert 0 in live_ne
        # NaN-free partitions still prune normally on the partition col
        assert len(prune_parts(E.cmp("n1", "<", 5), info)) < 4

    def test_unknown_exprs_never_prune(self):
        info = self._info()
        allp = info.all_parts()
        assert prune_parts(E.cmp("s1", "==", "abcd"), info) == allp
        assert prune_parts(E.col_cmp("n1", "<", "n2"), info) == allp
        assert prune_parts(E.TRUE, info) == allp

    def test_plan_helpers(self):
        s = make_session(partitioning=Partitioning("n1", "range", 4))
        plan = (s.table("t").filter(E.cmp("n1", "<", 50))
                .project("n1", "n2"))
        scan, pred = linear_scan_chain(plan)
        assert scan.table == "t"
        assert E.canonical(pred) == E.canonical(E.cmp("n1", "<", 50))
        restricted = restrict_to_parts(plan, (1, 2))
        scan2, _ = linear_scan_chain(restricted)
        assert scan2.parts == (1, 2)
        # joins are not linear chains
        two = plan.join(s.table("t").project("n3"), "n1", "n3")
        assert linear_scan_chain(two) is None


# ---------------------------------------------------------------------------
# pruned == unpruned, property-tested (satellite 3)
# ---------------------------------------------------------------------------
def _make_pred(col, op, frac):
    """One comparison leaf from a (column, op, fraction) triple —
    shared by the hypothesis strategy and the seeded generator."""
    if col.startswith("d"):
        return E.cmp(col, op, float(np.float32(frac)))
    hi = {"n1": 1000, "n2": 10_000, "n3": 100_000}[col]
    # mix integral and fractional thresholds (fold_int_cmp path)
    v = frac * hi
    return E.cmp(col, op, float(v) if frac < 0.5 else int(v))


_PRED_COLS = ["n1", "n2", "n3", "d1", "d2"]
_PRED_OPS = ["<", "<=", ">", ">=", "==", "!="]


def _random_pred(rng: np.random.Generator, depth: int = 2):
    """Seeded random predicate tree over the same space the hypothesis
    strategy draws from (always-run fallback when hypothesis is not
    installed)."""
    kind = rng.integers(0, 4) if depth > 0 else 3
    if kind == 3:
        return _make_pred(_PRED_COLS[rng.integers(len(_PRED_COLS))],
                          _PRED_OPS[rng.integers(len(_PRED_OPS))],
                          float(rng.random()))
    parts = [_random_pred(rng, depth - 1)
             for _ in range(int(rng.integers(2, 4)))]
    if kind == 0:
        return E.and_(*parts)
    if kind == 1:
        return E.or_(*parts)
    return E.not_(parts[0])


_SESS = {}


def _sessions(fmt, scheme):
    """Session pair (pruned, unpruned) over the SAME partitioned layout
    — memoized: the property tests call this many times."""
    key = (fmt, scheme)
    if key not in _SESS:
        part = Partitioning("n1", scheme, 8)
        _SESS[key] = (make_session(fmt, part, prune=True),
                      make_session(fmt, part, prune=False))
    return _SESS[key]


def _assert_pruned_bit_identical(pred, fmt, scheme):
    pruned, unpruned = _sessions(fmt, scheme)
    q = lambda s: (s.table("t").filter(pred)
                   .project("n1", "n2", "d1"))
    a = pruned.run_batch([q(pruned)], mqo=False).results[0].table
    b = unpruned.run_batch([q(unpruned)], mqo=False).results[0].table
    assert a.nrows == b.nrows, E.pretty(pred)
    an, bn = a.to_numpy(), b.to_numpy()
    for c in an:
        np.testing.assert_array_equal(an[c], bn[c])


def _assert_prune_conservative(pred, scheme):
    """Direct oracle: evaluate the predicate per partition; any
    partition holding a qualifying row must survive pruning."""
    import jax.numpy as jnp

    part = Partitioning("n1", scheme, 8)
    _, reordered, info = partition_table(part, NROWS, COLS)
    live = set(prune_parts(pred, info))
    cols = {n: jnp.asarray(v) for n, v in reordered.items()
            if v.ndim == 1}
    mask = np.asarray(E.eval_expr(pred, cols))
    for pid in range(info.n_partitions):
        lo, hi = info.part_range(pid)
        if mask[lo:hi].any():
            assert pid in live, (pid, E.pretty(pred))


class TestPrunedBitIdentitySeeded:
    """Always-run variant of the property tests (seeded generator over
    the same predicate space — CI also runs the hypothesis variant)."""

    @pytest.mark.parametrize("fmt", ["columnar", "csv"])
    @pytest.mark.parametrize("scheme", ["range", "hash"])
    def test_pruned_equals_unpruned_live_rows(self, fmt, scheme):
        rng = np.random.default_rng(0)
        for _ in range(12):
            _assert_pruned_bit_identical(_random_pred(rng), fmt, scheme)

    @pytest.mark.parametrize("scheme", ["range", "hash"])
    def test_prune_never_drops_qualifying_partitions(self, scheme):
        rng = np.random.default_rng(1)
        for _ in range(40):
            _assert_prune_conservative(_random_pred(rng), scheme)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st_

    _HYP = True
except ImportError:                      # pragma: no cover - CI has it
    _HYP = False

if _HYP:
    def _pred_strategy():
        leaf = st_.builds(
            _make_pred, st_.sampled_from(_PRED_COLS),
            st_.sampled_from(_PRED_OPS),
            st_.floats(min_value=0.0, max_value=1.0, allow_nan=False,
                       width=32))
        return st_.recursive(
            leaf,
            lambda children: st_.one_of(
                st_.lists(children, min_size=2, max_size=3).map(
                    lambda ps: E.and_(*ps)),
                st_.lists(children, min_size=2, max_size=3).map(
                    lambda ps: E.or_(*ps)),
                children.map(E.not_),
            ),
            max_leaves=4)

    class TestPrunedBitIdentity:
        @settings(max_examples=25, deadline=None,
                  suppress_health_check=list(HealthCheck))
        @given(pred=_pred_strategy(),
               fmt=st_.sampled_from(["columnar", "csv"]),
               scheme=st_.sampled_from(["range", "hash"]))
        def test_pruned_equals_unpruned_live_rows(self, pred, fmt,
                                                  scheme):
            _assert_pruned_bit_identical(pred, fmt, scheme)

        @settings(max_examples=20, deadline=None,
                  suppress_health_check=list(HealthCheck))
        @given(pred=_pred_strategy(),
               scheme=st_.sampled_from(["range", "hash"]))
        def test_prune_never_drops_qualifying_partitions(self, pred,
                                                         scheme):
            _assert_prune_conservative(pred, scheme)


# ---------------------------------------------------------------------------
# partition-grained MCKP + partial residency
# ---------------------------------------------------------------------------
def _dashboard(sess):
    t = lambda: sess.table("t")
    return [
        t().filter(E.cmp("n1", "<", 400)).project("n1", "n2", "n3", "d1"),
        t().filter(E.cmp("n1", "<", 300)).project("n1", "n2", "d2"),
        t().filter(E.cmp("n1", "<", 350)).project("n1", "n3", "d1"),
    ]


def _partitioned_csv_session(budget):
    return make_session("csv", Partitioning("n1", "range", 8),
                        budget=budget, disk_latency=5e-9)


class TestPartitionGrainedMckp:
    def test_full_budget_admits_all_live_partitions(self):
        sess = _partitioned_csv_session(1 << 30)
        r = sess.run_batch(_dashboard(sess), mqo=True)
        rep = r.mqo.report
        assert rep.n_partitioned >= 1
        assert rep.n_partition_items >= 2
        ce = next(c for c in r.mqo.rewritten.ces
                  if c.partition_detail is not None)
        live = ce.partition_detail[0].live
        assert 0 < len(live) < 8            # pruning cut some partitions
        assert ce.admitted_partitions == frozenset(live)

    def test_small_budget_admits_strict_subset(self):
        big = _partitioned_csv_session(1 << 30)
        rb = big.run_batch(_dashboard(big), mqo=True)
        full_w = sum(sl.weight for ce in rb.mqo.rewritten.ces
                     if ce.partition_detail
                     for sl in ce.partition_detail[1])
        assert full_w > 0
        sess = _partitioned_csv_session(max(full_w // 3, 1 << 12))
        r = sess.run_batch(_dashboard(sess), mqo=True)
        ce = next(c for c in r.mqo.rewritten.ces
                  if c.partition_detail is not None)
        adm, live = ce.admitted_partitions, ce.partition_detail[0].live
        assert 0 < len(adm) < len(live)     # the hot FRACTION, not all
        # partial hit composes resident + recomputed: results correct
        base = sess.run_batch(_dashboard(sess), mqo=False)
        for a, b in zip(base.results, r.results):
            assert a.table.row_multiset() == b.table.row_multiset()

    def test_warm_window_reprices_resident_partitions(self):
        big = _partitioned_csv_session(1 << 30)
        full_w = sum(sl.weight
                     for ce in big.run_batch(_dashboard(big),
                                             mqo=True).mqo.rewritten.ces
                     if ce.partition_detail
                     for sl in ce.partition_detail[1])
        sess = _partitioned_csv_session(max(full_w // 3, 1 << 12))
        r1 = sess.run_batch(_dashboard(sess), mqo=True)
        parts = sess.ce_resident_parts()
        assert parts and all(v for v in parts.values())
        r2 = sess.run_batch(_dashboard(sess), mqo=True)
        assert r2.mqo.report.n_resident_parts >= 1
        assert r2.metrics.bytes_cached_read > 0
        base = sess.run_batch(_dashboard(sess), mqo=False)
        for a, b in zip(base.results, r2.results):
            assert a.table.row_multiset() == b.table.row_multiset()

    def test_mqo_results_bitwise_stable_under_budgets(self):
        """Tiny vs unlimited budget: partition admission differs, the
        results must not (memory-hierarchy invariant extended to
        partition-grained entries)."""
        tiny = _partitioned_csv_session(1 << 14)
        huge = _partitioned_csv_session(1 << 30)
        rt = tiny.run_batch(_dashboard(tiny), mqo=True)
        rh = huge.run_batch(_dashboard(huge), mqo=True)
        for a, b in zip(rt.results, rh.results):
            assert a.table.row_multiset() == b.table.row_multiset()
        assert tiny.memory.device_used <= tiny.memory.device_budget

    def test_prune_false_disables_partition_grained_mqo(self):
        """ExecutionConfig.prune=False must force the unpruned path on
        the MQO route too: no CE partitioning, no partition-restricted
        scans — whole-CE behavior, bit-comparable to PR 3."""
        sess = make_session("csv", Partitioning("n1", "range", 8),
                            prune=False, budget=1 << 30,
                            disk_latency=5e-9)
        r = sess.run_batch(_dashboard(sess), mqo=True)
        assert r.mqo.report.n_partitioned == 0
        assert r.mqo.report.n_partition_items == 0
        assert all(ce.partition_detail is None
                   for ce in r.mqo.rewritten.ces)
        base = sess.run_batch(_dashboard(sess), mqo=False)
        for a, b in zip(base.results, r.results):
            assert a.table.row_multiset() == b.table.row_multiset()

    def test_explain_reports_partitions(self):
        sess = _partitioned_csv_session(1 << 30)
        svc = QueryService(sess, max_batch=len(_dashboard(sess)))
        handles = [svc.submit(q) for q in _dashboard(sess)]
        svc.flush()
        ex = handles[0].explain()
        ce_with_parts = [c for c in ex["ces"] if "partitions" in c]
        assert ce_with_parts
        info = ce_with_parts[0]["partitions"]
        assert set(info["admitted"]) <= set(info["live"])


# ---------------------------------------------------------------------------
# re-registration invalidation (satellite 2)
# ---------------------------------------------------------------------------
class TestReregisterInvalidation:
    def test_reregister_drops_partition_state(self):
        sess = _partitioned_csv_session(1 << 30)
        sess.run_batch(_dashboard(sess), mqo=True)
        assert sess.ce_resident_parts()
        assert "t" in sess.stats.partitions
        assert any(isinstance(k, tuple) and k[1] == "__csv__"
                   for k in sess._scan_pool.keys())

        # new data under the same name: different seed, no partitioning
        cols2 = generate_columns(SCHEMA, NROWS, seed=99)
        st2, _ = make_storage("t", SCHEMA, NROWS, "csv", cols=cols2)
        sess.register(st2, columnar_for_stats=cols2)
        assert not sess.ce_resident_parts()          # CE entries gone
        assert "t" not in sess.stats.partitions      # per-part stats gone
        assert not any(k[0] == "t" for k in sess._scan_pool.keys())
        # fresh execution serves the NEW data
        q = sess.table("t").filter(E.cmp("n1", "<", 300)).project("n1")
        got = sess.run_batch([q], mqo=True).results[0].table
        want = np.sort(cols2["n1"][cols2["n1"] < 300])
        np.testing.assert_array_equal(np.sort(got.to_numpy()["n1"]), want)

    def test_reregister_with_new_partitioning_reprunes(self):
        sess = make_session(partitioning=Partitioning("n1", "range", 8))
        assert sess.stats.partitions["t"].n_partitions == 8
        st2, _ = make_storage("t", SCHEMA, NROWS, "columnar", cols=COLS)
        sess.register(st2, columnar_for_stats=COLS,
                      partitioning=Partitioning("n2", "hash", 4))
        info = sess.stats.partitions["t"]
        assert info.n_partitions == 4 and info.spec.column == "n2"
        q = sess.table("t").filter(E.cmp("n2", "==", 77)).project("n2")
        got = sess.run_batch([q], mqo=False).results[0].table
        assert got.nrows == int((COLS["n2"] == 77).sum())


# ---------------------------------------------------------------------------
# multi-device sharded scans (subprocess: 8 host devices)
# ---------------------------------------------------------------------------
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_multi_device(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
class TestShardedPartitionedScan:
    def test_sharded_pruned_matches_single_device_unpruned(self):
        out = _run_multi_device("""
            import numpy as np, jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_test_mesh
            from repro.relational import (ExecutionConfig, MemoryConfig,
                Partitioning, Session, SessionConfig, expr as E,
                make_storage)
            from repro.relational.datagen import (generate_columns,
                synthetic_schema)

            schema = synthetic_schema(n_int=3, n_dbl=1, n_str=1)
            cols = generate_columns(schema, 8192, seed=3)
            part = Partitioning("n1", "range", 8)
            mesh = make_test_mesh((8,), ("data",))
            sharding = NamedSharding(mesh, P("data"))

            def mk(shard, prune):
                s = Session.from_config(SessionConfig(
                    execution=ExecutionConfig(
                        sharding=shard, prune=prune),
                    memory=MemoryConfig(budget_bytes=1 << 26)))
                st, _ = make_storage("t", schema, 8192, "columnar",
                                     cols=cols)
                s.register(st, columnar_for_stats=cols,
                           partitioning=part)
                return s

            plain = mk(None, False)       # single-device, unpruned
            sharded = mk(sharding, True)  # multi-device, pruned

            preds = [
                E.cmp("n1", "<", 200),
                E.and_(E.cmp("n1", ">", 100), E.cmp("d1", "<", 0.5)),
                E.or_(E.cmp("n1", "<", 50), E.cmp("n1", ">", 900)),
            ]
            q = lambda s, p: (s.table("t").filter(p)
                              .project("n1", "n2", "d1"))
            r1 = plain.run_batch([q(plain, p) for p in preds], mqo=False)
            r2 = sharded.run_batch([q(sharded, p) for p in preds],
                                   mqo=False)
            for a, b in zip(r1.results, r2.results):
                assert a.table.nrows == b.table.nrows
                an, bn = a.table.to_numpy(), b.table.to_numpy()
                for c in an:
                    np.testing.assert_array_equal(an[c], bn[c])
                # sharded execution really placed rows on all devices
                arr = b.table.columns["n1"]
            # MQO path: worksharing on the sharded session stays correct
            fam = [q(sharded, E.cmp("n1", "<", v))
                   for v in (300, 350, 400)]
            fam_ref = [q(plain, E.cmp("n1", "<", v))
                       for v in (300, 350, 400)]
            rs = sharded.run_batch(fam, mqo=True)
            rr = plain.run_batch(fam_ref, mqo=False)
            for a, b in zip(rr.results, rs.results):
                assert a.table.row_multiset() == b.table.row_multiset()
            print("SHARDED_PARTITION_OK")
        """)
        assert "SHARDED_PARTITION_OK" in out

    def test_scan_placed_across_devices(self):
        out = _run_multi_device("""
            import numpy as np, jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.mesh import make_test_mesh
            from repro.relational import (ExecutionConfig, MemoryConfig,
                Partitioning, Session, SessionConfig, expr as E,
                make_storage)
            from repro.relational.datagen import (generate_columns,
                synthetic_schema)
            from repro.relational.physical import ExecContext, execute

            schema = synthetic_schema(n_int=2, n_dbl=0, n_str=0)
            cols = generate_columns(schema, 4096, seed=5)
            mesh = make_test_mesh((8,), ("data",))
            sharding = NamedSharding(mesh, P("data"))
            s = Session.from_config(SessionConfig(
                execution=ExecutionConfig(sharding=sharding),
                memory=MemoryConfig(budget_bytes=1 << 26)))
            st, _ = make_storage("t", schema, 4096, "columnar", cols=cols)
            s.register(st, columnar_for_stats=cols,
                       partitioning=Partitioning("n1", "range", 8))
            ctx = s._fresh_ctx()
            table = execute(s.table("t").filter(
                E.cmp("n1", ">", 0)).project("n1", "n2"), ctx)
            # the scan's device buffers span the whole mesh
            src = ctx.scan_cache
            sharded_cols = [e.payload for e in src.entries.values()]
            assert sharded_cols, "scan cache empty"
            spans = {len(c.sharding.device_set) for c in sharded_cols}
            assert 8 in spans, spans
            print("PLACEMENT_OK")
        """)
        assert "PLACEMENT_OK" in out
