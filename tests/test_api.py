"""Fluent Relation frontend (ISSUE 5): builder semantics, operator
overloading, lazy/immutable behavior, cache hints, and the legacy
compat shims (raw logical.Node submission + legacy Session kwargs)
with their DeprecationWarnings and bit-identity guarantees.
"""
import warnings

import numpy as np
import pytest

from repro.core.fingerprint import strict_fingerprint
from repro.relational import (I32, ColExpr, MemoryConfig, Pred,
                              QueryService, Relation, Schema, Session,
                              SessionConfig, c, canonicalize_plan, col,
                              expr as E, logical as L, make_storage)

S = Schema.of(("a", I32), ("b", I32), ("d", I32))


def _mk_session(budget=1 << 24, nrows=2000):
    rng = np.random.default_rng(5)
    cols = {n: rng.integers(0, 100, nrows).astype(np.int32)
            for n in ("a", "b", "d")}
    sess = Session.from_config(
        SessionConfig.from_legacy_kwargs(budget_bytes=budget))
    st, _ = make_storage("t", S, nrows, "columnar", cols=cols)
    sess.register(st)
    return sess, cols


# ---------------------------------------------------------------------------
# column expressions
# ---------------------------------------------------------------------------
class TestColumnExpressions:
    def test_namespace_and_col_helper(self):
        assert isinstance(c.price, ColExpr)
        assert c["net profit"].name == "net profit"
        assert col("qty").name == "qty"

    def test_comparison_builds_pred(self):
        p = c.a > 5
        assert isinstance(p, Pred)
        assert p.expr == E.cmp("a", ">", 5)
        assert (c.a == c.b).expr == E.col_cmp("a", "==", "b")

    def test_literal_on_left_reflected_dispatch(self):
        # Python reflects 5 < c.a into ColExpr.__gt__(5)
        assert (5 < c.a).expr == E.cmp("a", ">", 5)
        assert (5 == c.a).expr == E.cmp("a", "==", 5)

    def test_connectives(self):
        p = (c.a > 5) & (c.b == 3) | ~(c.d < 1)
        assert isinstance(p, Pred)
        got = canonicalize_plan(
            L.scan("t", S).filter(p.expr)).pred
        want = canonicalize_plan(L.scan("t", S).filter(
            E.or_(E.and_(E.cmp("a", ">", 5), E.cmp("b", "==", 3)),
                  E.cmp("d", ">=", 1)))).pred
        assert got == want

    def test_isin_between(self):
        # isin builds the first-class membership node (one kernel
        # opcode); canonicalization dedups + sorts the value set and
        # folds a singleton down to a plain compare
        from repro.relational import canonicalize_expr
        assert (c.a.isin([2, 1])).expr == E.In(E.Col("a"), (2, 1))
        assert (canonicalize_expr(c.a.isin([2, 1, 2]).expr)
                == E.In(E.Col("a"), (1, 2)))
        assert (canonicalize_expr(c.a.isin([7]).expr)
                == E.cmp("a", "==", 7))
        assert ((c.a.between(3, 7)).expr
                == E.and_(E.cmp("a", ">=", 3), E.cmp("a", "<=", 7)))

    def test_isin_empty_is_false_and_executes(self):
        # empty membership canonicalizes to FALSE and returns no rows
        from repro.relational import canonicalize_expr
        assert (c.a.isin([])).expr == E.In(E.Col("a"), ())
        assert canonicalize_expr(c.a.isin([]).expr) == E.Not(E.TRUE)
        sess, _ = _mk_session()
        out = sess.run_one(
            sess.table("t").where(c.a.isin([])).select("a"))
        assert out.table.nrows == 0

    def test_bool_coercion_raises(self):
        with pytest.raises(TypeError):
            bool(c.a > 5)

    def test_invalid_operand_fails_at_call_site(self):
        # review fix: comparing a column against a non-literal must
        # raise here, not deep inside fingerprinting
        with pytest.raises(TypeError, match="cannot compare column"):
            c.a == (c.b > 5)
        with pytest.raises(TypeError, match="cannot compare column"):
            c.a > [1, 2]

    def test_non_finite_literals_rejected(self):
        for bad in (float("nan"), float("inf"), -float("inf")):
            with pytest.raises(ValueError, match="non-finite"):
                c.a > bad

    def test_numpy_scalars_coerce_to_canonical_literals(self):
        assert (c.a > np.int64(5)).expr == E.cmp("a", ">", 5)
        assert (c.a > np.float32(5.5)).expr == E.cmp("a", ">", 5.5)


# ---------------------------------------------------------------------------
# the Relation builder
# ---------------------------------------------------------------------------
class TestRelationBuilder:
    def test_table_returns_bound_relation(self):
        sess, _ = _mk_session()
        rel = sess.table("t")
        assert isinstance(rel, Relation)
        assert rel.session is sess
        assert rel.columns == ("a", "b", "d")

    def test_builder_is_immutable(self):
        sess, _ = _mk_session()
        rel = sess.table("t")
        filtered = rel.where(c.a > 5)
        assert filtered is not rel
        assert isinstance(rel.plan, L.Scan)         # base unchanged
        assert isinstance(filtered.plan, L.Filter)

    def test_full_chain_compiles(self):
        sess, _ = _mk_session()
        rel = (sess.table("t").where(c.a > 5).select("a", "b")
               .group_by("a").agg(("n", "count", ""), ("s", "sum", "b"))
               .sort("a").limit(10))
        plan = rel.logical_plan()
        assert isinstance(plan, L.Limit)
        text = rel.explain_str()
        assert "Aggregate" in text and "Filter" in text

    def test_union_and_join_accept_relations_and_nodes(self):
        sess, _ = _mk_session()
        rel = sess.table("t").where(c.a > 90).select("a")
        u = rel.union(sess.table("t").where(c.a < 5).select("a"))
        assert isinstance(u.plan, L.Union)
        other = L.scan("u", Schema.of(("x", I32)))
        j = sess.table("t").join(other, "a", "x")
        assert isinstance(j.plan, L.Join)

    def test_collect_executes_on_bound_session(self):
        sess, cols = _mk_session()
        out = sess.table("t").where(c.a > 50).select("a").collect()
        assert out.nrows == int((cols["a"] > 50).sum())

    def test_select_rejects_duplicate_columns(self):
        sess, _ = _mk_session()
        with pytest.raises(ValueError, match="duplicate"):
            sess.table("t").select("a", "a")

    def test_run_batch_accepts_iterators(self):
        # review fix: a generator input must not be exhausted by the
        # coercion pass and silently yield an empty batch
        sess, cols = _mk_session()
        rels = (sess.table("t").where(c.a > v).select("a")
                for v in (10, 20))
        res = sess.run_batch(rels)
        assert len(res.results) == 2
        assert res.results[0].table.nrows == int((cols["a"] > 10).sum())

    def test_collect_unbound_raises(self):
        rel = Relation(L.scan("t", S))
        with pytest.raises(RuntimeError):
            rel.collect()

    def test_legacy_builder_methods_alias(self):
        sess, _ = _mk_session()
        a = sess.table("t").filter(E.cmp("a", ">", 5)).project("a")
        b = sess.table("t").where(c.a > 5).select("a")
        assert (strict_fingerprint(a.logical_plan())
                == strict_fingerprint(b.logical_plan()))


# ---------------------------------------------------------------------------
# legacy-surface shims
# ---------------------------------------------------------------------------
class TestLegacyShims:
    def test_raw_node_submit_warns_and_is_bit_identical(self):
        sess, _ = _mk_session()
        raw = (sess.scan_node("t").filter(E.cmp("a", ">", 50))
               .project("a", "b"))
        rel = sess.table("t").where(c.a > 50).select("a", "b")
        with pytest.warns(DeprecationWarning, match="Relation API"):
            legacy = sess.run_batch([raw])
        fresh, _ = _mk_session()
        modern = fresh.run_batch(
            [fresh.table("t").where(c.a > 50).select("a", "b")])
        ta = legacy.results[0].table
        tb = modern.results[0].table
        assert ta.schema.names == tb.schema.names
        for n in ta.schema.names:
            np.testing.assert_array_equal(
                np.asarray(ta.columns[n])[: ta.nrows],
                np.asarray(tb.columns[n])[: tb.nrows])
        # same session, same strict identity for both spellings
        assert (strict_fingerprint(canonicalize_plan(raw))
                == strict_fingerprint(rel.logical_plan()))

    def test_service_submit_raw_node_warns(self):
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=1)
        with pytest.warns(DeprecationWarning):
            h = svc.submit(sess.scan_node("t").filter(E.cmp("a", ">", 0)))
        assert h.done

    def test_relation_submission_does_not_warn(self):
        sess, _ = _mk_session()
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            sess.run_batch([sess.table("t").where(c.a > 0).select("a")])

    def test_legacy_session_kwargs_warn(self):
        with pytest.warns(DeprecationWarning, match="SessionConfig"):
            sess = Session(budget_bytes=1 << 20, policy="benefit")
        assert sess.budget == 1 << 20
        assert sess.config.memory.policy == "benefit"

    def test_default_session_does_not_warn(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            Session()

    def test_config_plus_legacy_kwargs_still_raises(self):
        with pytest.raises(ValueError):
            Session(budget_bytes=1 << 20,
                    config=SessionConfig(
                        memory=MemoryConfig(budget_bytes=1 << 22)))

    def test_legacy_and_config_paths_agree(self):
        with pytest.warns(DeprecationWarning):
            a = Session(budget_bytes=1 << 22, policy="benefit",
                        retain_across_batches=False)
        b = Session.from_config(SessionConfig.from_legacy_kwargs(
            budget_bytes=1 << 22, policy="benefit",
            retain_across_batches=False))
        assert a.config == b.config


# ---------------------------------------------------------------------------
# cache hints
# ---------------------------------------------------------------------------
class TestCacheHint:
    def test_cache_hint_is_immutable_marker(self):
        sess, _ = _mk_session()
        rel = sess.table("t").where(c.a > 50).select("a", "b")
        hinted = rel.cache_hint()
        assert hinted.hint_cache and not rel.hint_cache

    def test_hinted_single_query_materializes_then_resumes(self):
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=1)
        rel = sess.table("t").where(c.a > 50).select("a", "b")
        h1 = svc.submit(rel.cache_hint())       # lone query, k drops to 1
        ces = {ce["strict_psi"] for ce in h1.explain()["ces"]}
        assert ces, "hinted lone query should build a covering entry"
        # the same query (unhinted) in a later window resumes from it
        h2 = svc.submit(rel)
        ex = h2.explain()
        assert {ce["strict_psi"] for ce in ex["ces"]} == ces
        assert ex["resident_reuse"]

    def test_unhinted_single_query_builds_no_ce(self):
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=1)
        h = svc.submit(sess.table("t").where(c.a > 50).select("a", "b"))
        assert not h.explain()["ces"]


# ---------------------------------------------------------------------------
# handle explain provenance
# ---------------------------------------------------------------------------
class TestExplainProvenance:
    def test_submitted_vs_executed_plan(self):
        sess, _ = _mk_session()
        svc = QueryService(sess, max_batch=2)
        rel = sess.table("t").where(c.a > 50).select("a", "b")
        h1, h2 = svc.submit(rel), svc.submit(rel)
        ex = h1.explain()
        assert "Scan" not in ex["plan"] or "cached" in ex["plan"] \
            or ex["ces"] == []
        assert ex["submitted"].startswith("project")
        assert h1.plan is rel               # provenance: as submitted
        assert isinstance(h1.node, L.Node)
